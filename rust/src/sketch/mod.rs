//! Gradient compressors — the paper's core contribution plus every baseline.
//!
//! All compressors implement [`Compressor`]: a deterministic (seeded) linear
//! map `R^p → R^k` applied to per-sample gradients. The paper's taxonomy:
//!
//! | Name (paper) | Type | Complexity | Here |
//! |---|---|---|---|
//! | `RM_k` (Random Mask) | sparsification | O(k) | [`mask::RandomMask`] |
//! | `SM_k` (Selective Mask) | sparsification | O(k) | [`selective::SelectiveMask`] |
//! | `SJLT_k` | sparse projection | O(p·s) | [`sjlt::Sjlt`] |
//! | `GraSS = SJLT_k ∘ MASK_k'` | two-stage | O(k') | [`grass::Grass`] |
//! | `GAUSS_k` | dense baseline | O(pk) | [`gauss::GaussianProjection`] |
//! | `FJLT_k` | structured baseline | O((p+k)log p) | [`fjlt::Fjlt`] |
//! | `LoGra = GAUSS_{kin⊗kout}` | factorized baseline | O(√(p_l k_l)) | [`logra::LoGra`] |
//! | `FactGraSS = SJLT ∘ MASK_{kin'⊗kout'}` | factorized two-stage | O(k'_l) | [`factgrass::FactGrass`] |
//!
//! The factorized compressors ([`FactorizedCompressor`]) consume the LoGra
//! interface — per-layer inputs `z_in ∈ R^{T×d_in}` and pre-activation
//! gradients `Dz_out ∈ R^{T×d_out}` — and never materialise the full
//! `d_in·d_out` gradient (paper §3.3.2).

pub mod factgrass;
pub mod fjlt;
pub mod gauss;
pub mod grass;
pub mod logra;
pub mod mask;
pub mod rng;
pub mod selective;
pub mod sjlt;
pub mod sparse;

pub use sparse::{SparseRows, SPARSE_DISPATCH_MAX_DENSITY};

use crate::models::shapes::ModelShapes;

/// Reusable per-worker workspace for the batch compression hot path.
///
/// Every tuned `compress_batch_with` kernel draws its temporaries (masked
/// intermediates, SJLT bucket/sign chunk tables, FWHT padding buffers,
/// factor projections) from here instead of allocating, so a long-running
/// compress worker performs **no steady-state heap allocation**: buffers are
/// taken, used, and returned, and the next batch reuses their capacity.
///
/// One instance belongs to one worker thread — kernels take it `&mut`, so
/// the type system forbids sharing (the pipeline keeps one per compress
/// worker). Kernels that parallelise internally split the scratch-owned
/// buffers into disjoint row ranges for their helper threads.
#[derive(Default)]
pub struct Scratch {
    /// Recycled f32 buffers (best-fit by capacity).
    f32_pool: Vec<Vec<f32>>,
    /// Recycled (u32, f32) tables — SJLT bucket/sign chunks, mask
    /// (coordinate, scale) gather tables.
    table_pool: Vec<Vec<(u32, f32)>>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a zeroed f32 buffer of exactly `len` elements, reusing pooled
    /// capacity when possible. Return it with [`Scratch::put_f32`].
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        // Best fit: the smallest pooled buffer that already holds `len`, so
        // a small request never consumes (and a later large request never
        // regrows) the pool's biggest allocation.
        let pos = self
            .f32_pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        let mut v = match pos {
            Some(i) => self.f32_pool.swap_remove(i),
            None => self.f32_pool.pop().unwrap_or_default(),
        };
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer taken with [`Scratch::take_f32`] to the pool.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.f32_pool.push(v);
    }

    /// Take a (bucket, sign) table of exactly `len` entries (contents
    /// unspecified — kernels overwrite before reading).
    pub fn take_table(&mut self, len: usize) -> Vec<(u32, f32)> {
        let pos = self
            .table_pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        let mut v = match pos {
            Some(i) => self.table_pool.swap_remove(i),
            None => self.table_pool.pop().unwrap_or_default(),
        };
        v.clear();
        v.resize(len, (0, 0.0));
        v
    }

    /// Return a table taken with [`Scratch::take_table`] to the pool.
    pub fn put_table(&mut self, v: Vec<(u32, f32)>) {
        self.table_pool.push(v);
    }
}

/// A seeded linear compression map `R^p → R^k` over dense gradient vectors.
pub trait Compressor: Send + Sync {
    /// Input dimensionality `p`.
    fn input_dim(&self) -> usize;
    /// Output (compressed) dimensionality `k`.
    fn output_dim(&self) -> usize;

    /// Compress `g` (len = `input_dim`) into `out` (len = `output_dim`).
    /// `out` is fully overwritten.
    fn compress_into(&self, g: &[f32], out: &mut [f32]);

    /// Convenience allocator form.
    fn compress(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(g, &mut out);
        out
    }

    /// Compress `n` rows (`n × p` → `n × k`) with a throwaway workspace.
    /// Callers on the hot path should hold a [`Scratch`] and use
    /// [`Compressor::compress_batch_with`] instead.
    fn compress_batch(&self, gs: &[f32], n: usize, out: &mut [f32]) {
        let mut scratch = Scratch::new();
        self.compress_batch_with(gs, n, out, &mut scratch);
    }

    /// Batch-first entry point: compress `n` rows (`n × p` → `n × k`),
    /// drawing all temporaries from `scratch` so steady-state compression
    /// is allocation-free. The default falls back to a row-parallel loop
    /// over [`Compressor::compress_into`]; every production compressor
    /// overrides it with a tuned kernel that amortises projector setup
    /// across the whole batch (chunked bucket/sign tables for SJLT, blocked
    /// matmul for GAUSS, shared sign/FWHT buffers for FJLT, hoisted mask
    /// intermediates for GraSS).
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        let p = self.input_dim();
        let k = self.output_dim();
        assert_eq!(gs.len(), n * p);
        assert_eq!(out.len(), n * k);
        crate::util::par::par_chunks_mut(out, k, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let i = row_start + off;
                self.compress_into(&gs[i * p..(i + 1) * p], orow);
            }
        });
    }

    /// Compress a sparse input given as (indices, values) pairs. The default
    /// densifies; SJLT and masks override with nnz-scaling implementations —
    /// this is the paper's "complexity scales with nnz(g)" property (§3.1).
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        let mut dense = vec![0.0; self.input_dim()];
        for (&i, &v) in idx.iter().zip(vals) {
            dense[i as usize] = v;
        }
        self.compress_into(&dense, out);
    }

    /// Batch-first sparse entry point: compress a CSR batch of
    /// [`SparseRows`] (`rows.n() × p` → `rows.n() × k`) without ever
    /// touching zero coordinates. The default densifies into the workspace
    /// and falls back to [`Compressor::compress_batch_with`]; the
    /// sparsity-native compressors (SJLT, masks, GraSS) override it with
    /// nnz-proportional kernels — the `O(s·nnz(g))` complexity of §3.1.
    fn compress_sparse_batch_with(
        &self,
        rows: &SparseRows,
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let (p, k, n) = (self.input_dim(), self.output_dim(), rows.n());
        assert_eq!(rows.dim(), p, "sparse batch dimension mismatch");
        assert_eq!(out.len(), n * k);
        let mut dense = scratch.take_f32(n * p);
        rows.densify_into(&mut dense);
        self.compress_batch_with(&dense, n, out, scratch);
        scratch.put_f32(dense);
    }

    /// Whether the pipeline's auto-dispatcher should consider converting a
    /// **dense** batch to CSR for this compressor at all. Only `true` when
    /// the dense batch kernel's per-row cost scales with the input width
    /// `p`, so skipping zeros can win (SJLT's `O(p)` scan). Compressors
    /// whose dense batch path is already sub-linear in `p` — the `O(k)`
    /// mask gathers, GraSS's `O(k')` masked pipeline — and compressors
    /// without a native CSR kernel (Gauss, FJLT) keep the default `false`:
    /// for them the `O(n·p)` probe + conversion costs more than the dense
    /// kernel, so the pipeline skips the probe entirely. Natively sparse
    /// sources that already hold CSR rows bypass this and call
    /// [`Compressor::compress_sparse_batch_with`] directly.
    fn sparse_dispatch_viable(&self) -> bool {
        false
    }

    /// Human-readable method name used in experiment reports.
    fn name(&self) -> String;
}

/// A factorized compressor for linear layers: consumes the layer's input
/// activations `x ∈ R^{T×d_in}` (row-major) and pre-activation gradients
/// `dy ∈ R^{T×d_out}` and emits the compressed per-sample gradient of the
/// weight matrix, without materialising the `d_out×d_in` gradient.
pub trait FactorizedCompressor: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;
    /// Compressed dimension `k_l`.
    fn output_dim(&self) -> usize;

    /// `x`: `T × d_in` row-major; `dy`: `T × d_out` row-major.
    /// `out` (len = `output_dim`) is fully overwritten.
    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]);

    fn compress(&self, t: usize, x: &[f32], dy: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(t, x, dy, &mut out);
        out
    }

    /// Batch-first entry point: compress `n` samples at once.
    ///
    /// `x` is `n × t × d_in` row-major, `dy` is `n × t × d_out` row-major.
    /// Sample `i` writes its `output_dim()` values at
    /// `out[i·out_stride + out_off ..]` — the strided layout lets the cache
    /// pipeline hand one `count × k_total` block to a stack of per-layer
    /// compressors, each filling its own column band (`out_stride = k_total`,
    /// `out_off` = the layer's offset). All temporaries come from `scratch`.
    ///
    /// The default loops over [`FactorizedCompressor::compress_into`];
    /// tuned kernels batch the factor projections across all `n·t`
    /// timesteps and hoist the per-sample reconstruction buffers into the
    /// workspace.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        _scratch: &mut Scratch,
    ) {
        let k = self.output_dim();
        let (d_in, d_out) = (self.d_in(), self.d_out());
        assert_eq!(x.len(), n * t * d_in);
        assert_eq!(dy.len(), n * t * d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        for i in 0..n {
            let base = i * out_stride + out_off;
            self.compress_into(
                t,
                &x[i * t * d_in..(i + 1) * t * d_in],
                &dy[i * t * d_out..(i + 1) * t * d_out],
                &mut out[base..base + k],
            );
        }
    }

    /// Batch-first sparse entry point: both factor sides arrive as CSR
    /// batches over the `n·t` timestep rows (`x`: width `d_in`, `dy`:
    /// width `d_out`). Output layout matches
    /// [`FactorizedCompressor::compress_batch_with`]. The default densifies
    /// both sides into the workspace and falls back to the dense batch
    /// kernel; the factorized family overrides it to sparsify / project
    /// each factor side in `O(nnz)` per timestep row.
    #[allow(clippy::too_many_arguments)]
    fn compress_sparse_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &SparseRows,
        dy: &SparseRows,
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        scratch: &mut Scratch,
    ) {
        let (d_in, d_out) = (self.d_in(), self.d_out());
        assert_eq!(x.n(), n * t, "x row count mismatch");
        assert_eq!(dy.n(), n * t, "dy row count mismatch");
        assert_eq!(x.dim(), d_in, "x factor dimension mismatch");
        assert_eq!(dy.dim(), d_out, "dy factor dimension mismatch");
        let mut xd = scratch.take_f32(n * t * d_in);
        let mut dyd = scratch.take_f32(n * t * d_out);
        x.densify_into(&mut xd);
        dy.densify_into(&mut dyd);
        self.compress_batch_with(n, t, &xd, &dyd, out, out_stride, out_off, scratch);
        scratch.put_f32(xd);
        scratch.put_f32(dyd);
    }

    /// See [`Compressor::sparse_dispatch_viable`]: `true` only when the
    /// dense batch kernel's per-row cost scales with the factor widths
    /// (LoGra's `O(d·k)` GEMMs, FactSjlt's `O(d·s)` scatters). The masked
    /// family (FactGraSS, FactMask) gathers `O(k')` per row regardless of
    /// `d`, so converting a dense batch can never pay for itself there.
    fn sparse_dispatch_viable(&self) -> bool {
        false
    }

    fn name(&self) -> String;
}

/// Which mask flavour a GraSS / FactGraSS instance uses for stage 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskKind {
    Random,
    Selective,
}

/// Which compressors the cache pipeline's compress stage applies — the
/// output of [`MethodSpec::build_bank`] and the one construction product
/// every consumer (coordinator, CLI, experiment harnesses, store
/// validation) shares.
pub enum CompressorBank {
    /// One flat compressor over the whole `p`-dimensional gradient.
    Flat(Box<dyn Compressor>),
    /// One factorized compressor per hooked layer; outputs concatenate.
    Factored(Vec<Box<dyn FactorizedCompressor>>),
}

impl CompressorBank {
    /// Total compressed row width `k` (factorized: `Σ_l k_l`).
    pub fn output_dim(&self) -> usize {
        match self {
            CompressorBank::Flat(c) => c.output_dim(),
            CompressorBank::Factored(cs) => cs.iter().map(|c| c.output_dim()).sum(),
        }
    }

    pub fn is_factored(&self) -> bool {
        matches!(self, CompressorBank::Factored(_))
    }

    /// The flat compressor, if this is a flat bank.
    pub fn as_flat(&self) -> Option<&dyn Compressor> {
        match self {
            CompressorBank::Flat(c) => Some(c.as_ref()),
            CompressorBank::Factored(_) => None,
        }
    }

    /// The per-layer compressor stack, if this is a factorized bank.
    pub fn as_factored(&self) -> Option<&[Box<dyn FactorizedCompressor>]> {
        match self {
            CompressorBank::Flat(_) => None,
            CompressorBank::Factored(cs) => Some(cs),
        }
    }

    /// Consume into the per-layer stack, if factorized.
    pub fn into_factored(self) -> Option<Vec<Box<dyn FactorizedCompressor>>> {
        match self {
            CompressorBank::Flat(_) => None,
            CompressorBank::Factored(cs) => Some(cs),
        }
    }

    /// Per-layer compressed dims (the block-diagonal FIM layout); a flat
    /// bank is one block.
    pub fn layer_dims(&self) -> Vec<usize> {
        match self {
            CompressorBank::Flat(c) => vec![c.output_dim()],
            CompressorBank::Factored(cs) => cs.iter().map(|c| c.output_dim()).collect(),
        }
    }

    /// Whether the pipeline should density-probe dense gradient batches
    /// for this bank (see [`Compressor::sparse_dispatch_viable`]). A
    /// factorized bank probes only if **every** layer's CSR kernel can
    /// win — batches convert whole, so one gather-bound layer makes the
    /// conversion a net loss.
    pub fn sparse_dispatch_viable(&self) -> bool {
        match self {
            CompressorBank::Flat(c) => c.sparse_dispatch_viable(),
            CompressorBank::Factored(cs) => cs.iter().all(|c| c.sparse_dispatch_viable()),
        }
    }
}

/// Per-layer trained factor masks `(input indices, output indices)` for the
/// selective factorized variants (see [`MethodSpec::build_bank_masked`]).
pub type LayerMasks = [(Vec<u32>, Vec<u32>)];

/// Compression method selector used by configs, the CLI, the store
/// metadata, and every experiment harness — the crate's total spec
/// language. [`MethodSpec::parse`] / [`MethodSpec::spec_string`] roundtrip,
/// and [`MethodSpec::build_bank`] is the single place per-layer compressor
/// construction happens.
#[derive(Debug, Clone, PartialEq)]
pub enum MethodSpec {
    /// `RM_k`
    RandomMask { k: usize },
    /// `SM_k` (indices must be trained first; falls back to magnitude-free
    /// random selection if no trained mask is available).
    SelectiveMask { k: usize },
    /// `SJLT_k` with `s` non-zeros per column (paper uses s = 1).
    Sjlt { k: usize, s: usize },
    /// `GAUSS_k`
    Gauss { k: usize },
    /// `FJLT_k`
    Fjlt { k: usize },
    /// `GraSS = SJLT_k ∘ MASK_k'`
    Grass {
        k: usize,
        k_prime: usize,
        mask: MaskKind,
    },
    /// `FactGraSS = SJLT_{k_l} ∘ MASK_{k_in' ⊗ k_out'}` per hooked layer
    /// (§3.3.2). `k_in`/`k_out` are the intermediate factor mask dims
    /// (clamped per layer to `d_in`/`d_out`); `k` is the final per-layer
    /// compressed dim `k_l`.
    FactGrass {
        k: usize,
        k_in: usize,
        k_out: usize,
        mask: MaskKind,
    },
    /// `LoGra = GAUSS_{k_in ⊗ k_out}` per hooked layer (Choe et al. 2024).
    LoGra { k_in: usize, k_out: usize },
    /// `SJLT_{k_in ⊗ k_out}` per hooked layer (Table 1d baseline).
    FactSjlt { k_in: usize, k_out: usize },
    /// `MASK_{k_in ⊗ k_out}` per hooked layer — RM⊗ (random) or SM⊗
    /// (selective; trained factor masks come in through
    /// [`MethodSpec::build_bank_masked`]).
    FactMask {
        k_in: usize,
        k_out: usize,
        mask: MaskKind,
    },
}

fn mask_str(mask: &MaskKind) -> &'static str {
    match mask {
        MaskKind::Random => "rm",
        MaskKind::Selective => "sm",
    }
}

impl MethodSpec {
    /// Parse a CLI/config spec string. Flat family: `rm:k=2048`,
    /// `sm:k=2048`, `sjlt:k=4096,s=1`, `gauss:k=2048`, `fjlt:k=8192`,
    /// `grass:k=2048,kp=8192,mask=rm`. Factorized family (per hooked
    /// layer): `factgrass:kin=32,kout=32,kl=256,mask=rm`,
    /// `logra:kin=16,kout=16`, `factsjlt:kin=16,kout=16`,
    /// `factmask:kin=16,kout=16,mask=rm`.
    ///
    /// # Examples
    ///
    /// ```
    /// use grass::sketch::MethodSpec;
    ///
    /// let spec = MethodSpec::parse("sjlt:k=1024,s=1").unwrap();
    /// assert_eq!(spec, MethodSpec::Sjlt { k: 1024, s: 1 });
    /// // `spec_string` is the inverse: specs roundtrip canonically.
    /// assert_eq!(spec.spec_string(), "sjlt:k=1024,s=1");
    ///
    /// // Factorized specs carry per-layer factor dims.
    /// let fact = MethodSpec::parse("factgrass:kin=8,kout=8,kl=16").unwrap();
    /// assert!(fact.is_factorized());
    ///
    /// // Unknown methods and malformed items are descriptive errors.
    /// assert!(MethodSpec::parse("warp:k=3").is_err());
    /// assert!(MethodSpec::parse("sjlt:k").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        use anyhow::{anyhow, bail};
        let (head, rest) = s.split_once(':').unwrap_or((s, ""));
        let mut kv = std::collections::BTreeMap::new();
        for item in rest.split(',').filter(|t| !t.is_empty()) {
            let (k, v) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("bad spec item '{item}' in '{s}'"))?;
            kv.insert(k.trim(), v.trim());
        }
        let need = |key: &str| -> anyhow::Result<usize> {
            kv.get(key)
                .ok_or_else(|| anyhow!("spec '{s}' missing '{key}='"))?
                .parse()
                .map_err(|e| anyhow!("spec '{s}': bad {key}: {e}"))
        };
        let mask = || -> anyhow::Result<MaskKind> {
            Ok(match kv.get("mask").copied().unwrap_or("rm") {
                "rm" => MaskKind::Random,
                "sm" => MaskKind::Selective,
                other => bail!("spec '{s}': unknown mask '{other}'"),
            })
        };
        Ok(match head {
            "rm" | "random_mask" => MethodSpec::RandomMask { k: need("k")? },
            "sm" | "selective_mask" => MethodSpec::SelectiveMask { k: need("k")? },
            "sjlt" => MethodSpec::Sjlt {
                k: need("k")?,
                s: need("s").unwrap_or(1),
            },
            "gauss" => MethodSpec::Gauss { k: need("k")? },
            "fjlt" => MethodSpec::Fjlt { k: need("k")? },
            "grass" => MethodSpec::Grass {
                k: need("k")?,
                k_prime: need("kp")?,
                mask: mask()?,
            },
            "factgrass" => MethodSpec::FactGrass {
                k: need("kl")?,
                k_in: need("kin")?,
                k_out: need("kout")?,
                mask: mask()?,
            },
            "logra" => MethodSpec::LoGra {
                k_in: need("kin")?,
                k_out: need("kout")?,
            },
            "factsjlt" => MethodSpec::FactSjlt {
                k_in: need("kin")?,
                k_out: need("kout")?,
            },
            "factmask" => MethodSpec::FactMask {
                k_in: need("kin")?,
                k_out: need("kout")?,
                mask: mask()?,
            },
            other => bail!("unknown compression method '{other}'"),
        })
    }

    /// Canonical spec string (inverse of [`MethodSpec::parse`]).
    pub fn spec_string(&self) -> String {
        match self {
            MethodSpec::RandomMask { k } => format!("rm:k={k}"),
            MethodSpec::SelectiveMask { k } => format!("sm:k={k}"),
            MethodSpec::Sjlt { k, s } => format!("sjlt:k={k},s={s}"),
            MethodSpec::Gauss { k } => format!("gauss:k={k}"),
            MethodSpec::Fjlt { k } => format!("fjlt:k={k}"),
            MethodSpec::Grass { k, k_prime, mask } => {
                format!("grass:k={k},kp={k_prime},mask={}", mask_str(mask))
            }
            MethodSpec::FactGrass {
                k,
                k_in,
                k_out,
                mask,
            } => format!(
                "factgrass:kin={k_in},kout={k_out},kl={k},mask={}",
                mask_str(mask)
            ),
            MethodSpec::LoGra { k_in, k_out } => format!("logra:kin={k_in},kout={k_out}"),
            MethodSpec::FactSjlt { k_in, k_out } => {
                format!("factsjlt:kin={k_in},kout={k_out}")
            }
            MethodSpec::FactMask { k_in, k_out, mask } => {
                format!("factmask:kin={k_in},kout={k_out},mask={}", mask_str(mask))
            }
        }
    }

    /// Whether this spec builds per-layer [`FactorizedCompressor`]s (the
    /// LoGra-hook path) rather than one flat [`Compressor`].
    pub fn is_factorized(&self) -> bool {
        matches!(
            self,
            MethodSpec::FactGrass { .. }
                | MethodSpec::LoGra { .. }
                | MethodSpec::FactSjlt { .. }
                | MethodSpec::FactMask { .. }
        )
    }

    /// Nominal output dimension: the flat `k`, or the **per-layer** `k_l`
    /// for factorized specs (a bank over `L` layers emits
    /// [`MethodSpec::bank_output_dim`] total columns).
    pub fn output_dim(&self) -> usize {
        match self {
            MethodSpec::RandomMask { k }
            | MethodSpec::SelectiveMask { k }
            | MethodSpec::Sjlt { k, .. }
            | MethodSpec::Gauss { k }
            | MethodSpec::Fjlt { k }
            | MethodSpec::Grass { k, .. }
            | MethodSpec::FactGrass { k, .. } => *k,
            MethodSpec::LoGra { k_in, k_out }
            | MethodSpec::FactSjlt { k_in, k_out }
            | MethodSpec::FactMask { k_in, k_out, .. } => k_in * k_out,
        }
    }

    /// Per-layer output dim after clamping the factor dims to the layer
    /// shape — what [`MethodSpec::build_factorized`] will actually emit.
    pub fn layer_output_dim(&self, d_in: usize, d_out: usize) -> anyhow::Result<usize> {
        match *self {
            MethodSpec::FactGrass { k, .. } => Ok(k),
            MethodSpec::LoGra { k_in, k_out } | MethodSpec::FactMask { k_in, k_out, .. } => {
                Ok(k_in.min(d_in) * k_out.min(d_out))
            }
            MethodSpec::FactSjlt { k_in, k_out } => Ok(k_in * k_out),
            _ => anyhow::bail!(
                "flat spec '{}' has no per-layer output dim",
                self.spec_string()
            ),
        }
    }

    /// Total compressed row width a bank built against `shapes` emits,
    /// without constructing any projector state — used by the store's
    /// open-time validation.
    pub fn bank_output_dim(&self, shapes: &ModelShapes) -> anyhow::Result<usize> {
        if self.is_factorized() {
            let mut total = 0;
            for &(d_in, d_out) in &shapes.layers {
                total += self.layer_output_dim(d_in, d_out)?;
            }
            Ok(total)
        } else {
            Ok(self.output_dim())
        }
    }

    /// Instantiate the flat compressor for input dimension `p` and `seed`.
    ///
    /// # Panics
    /// On factorized specs — those build per-layer compressors through
    /// [`MethodSpec::build_bank`] / [`MethodSpec::build_factorized`].
    pub fn build(&self, p: usize, seed: u64) -> Box<dyn Compressor> {
        match *self {
            MethodSpec::RandomMask { k } => Box::new(mask::RandomMask::new(p, k, seed)),
            MethodSpec::SelectiveMask { k } => {
                // Untrained selective mask degenerates to a random mask with a
                // distinct stream; `build_with_scores` builds the trained
                // (graddot-score-backed) variant.
                Box::new(mask::RandomMask::new(p, k, rng::hash2(seed, 0x5E1E)))
            }
            MethodSpec::Sjlt { k, s } => Box::new(sjlt::Sjlt::new(p, k, s, seed)),
            MethodSpec::Gauss { k } => Box::new(gauss::GaussianProjection::new(p, k, seed)),
            MethodSpec::Fjlt { k } => Box::new(fjlt::Fjlt::new(p, k, seed)),
            MethodSpec::Grass { k, k_prime, mask } => {
                Box::new(grass::Grass::new(p, k_prime, k, mask, seed))
            }
            _ => panic!(
                "factorized spec '{}' cannot build a flat compressor; use build_bank",
                self.spec_string()
            ),
        }
    }

    /// Flat build routing selective (`sm`-masked) specs through the
    /// graddot-score-backed stage: `scores` are per-coordinate importance
    /// values (e.g. a trained [`selective::TrainedMask`]'s scores) and the
    /// top-k coordinates are kept. Non-selective specs ignore `scores`.
    pub fn build_with_scores(&self, p: usize, seed: u64, scores: &[f32]) -> Box<dyn Compressor> {
        assert_eq!(scores.len(), p, "need one importance score per coordinate");
        match *self {
            MethodSpec::SelectiveMask { k } => Box::new(
                selective::TrainedMask {
                    scores: scores.to_vec(),
                    corr_history: vec![],
                }
                .into_mask(p, k),
            ),
            MethodSpec::Grass {
                k,
                k_prime,
                mask: MaskKind::Selective,
            } => Box::new(grass::Grass::with_scores(p, scores, k_prime, k, seed)),
            _ => self.build(p, seed),
        }
    }

    /// Instantiate one per-layer factorized compressor for a `d_in × d_out`
    /// linear layer. Factor dims clamp to the layer shape, matching the
    /// paper's `(2k_in ∧ d_in) ⊗ (2k_out ∧ d_out)` convention.
    pub fn build_factorized(
        &self,
        d_in: usize,
        d_out: usize,
        seed: u64,
    ) -> anyhow::Result<Box<dyn FactorizedCompressor>> {
        use anyhow::{bail, ensure};
        Ok(match *self {
            MethodSpec::FactGrass {
                k,
                k_in,
                k_out,
                mask,
            } => {
                let (ki, ko) = (k_in.min(d_in), k_out.min(d_out));
                ensure!(
                    k <= ki * ko,
                    "spec '{}': k_l = {k} exceeds masked dim {ki}×{ko} (layer {d_in}×{d_out})",
                    self.spec_string()
                );
                Box::new(factgrass::FactGrass::new(d_in, d_out, ki, ko, k, mask, seed))
            }
            MethodSpec::LoGra { k_in, k_out } => Box::new(logra::LoGra::new(
                d_in,
                d_out,
                k_in.min(d_in),
                k_out.min(d_out),
                seed,
            )),
            MethodSpec::FactSjlt { k_in, k_out } => {
                Box::new(factgrass::FactSjlt::new(d_in, d_out, k_in, k_out, seed))
            }
            MethodSpec::FactMask { k_in, k_out, mask } => {
                // An untrained selective factor mask falls back to random
                // selection on a distinct stream (same convention as the
                // flat `sm` spec); trained masks come in through
                // `build_bank_masked`.
                let s = match mask {
                    MaskKind::Random => seed,
                    MaskKind::Selective => rng::hash2(seed, 0x5E1E),
                };
                Box::new(factgrass::FactMask::new(
                    d_in,
                    d_out,
                    k_in.min(d_in),
                    k_out.min(d_out),
                    s,
                ))
            }
            _ => bail!(
                "flat spec '{}' cannot build a factorized compressor; use build",
                self.spec_string()
            ),
        })
    }

    /// Build the full compressor bank for a model's gradient geometry —
    /// the **only** construction path the coordinator, CLI, store
    /// validation, and experiment harnesses use. Flat specs produce a
    /// [`CompressorBank::Flat`] over `shapes.p`; factorized specs produce
    /// one per-layer compressor per hooked layer (seeded per layer from
    /// `seed`, so cache and attribute reconstruct identical projections).
    ///
    /// # Examples
    ///
    /// ```
    /// use grass::models::shapes::ModelShapes;
    /// use grass::sketch::MethodSpec;
    ///
    /// // Flat spec over a p-dimensional gradient.
    /// let bank = MethodSpec::parse("rm:k=64")
    ///     .unwrap()
    ///     .build_bank(&ModelShapes::flat(4096), 7)
    ///     .unwrap();
    /// assert_eq!(bank.output_dim(), 64);
    ///
    /// // Factorized spec: one compressor per hooked layer, total width
    /// // Σ_l k_l (LoGra emits k_in × k_out per layer).
    /// let fact = MethodSpec::parse("logra:kin=4,kout=4")
    ///     .unwrap()
    ///     .build_bank(&ModelShapes::factored(vec![(32, 16), (16, 32)]), 7)
    ///     .unwrap();
    /// assert_eq!(fact.output_dim(), 2 * 16);
    ///
    /// // A factorized spec needs hooked layers; flat-only geometry is a
    /// // descriptive error, not a silently mis-sized bank.
    /// assert!(MethodSpec::parse("logra:kin=4,kout=4")
    ///     .unwrap()
    ///     .build_bank(&ModelShapes::flat(4096), 7)
    ///     .is_err());
    /// ```
    pub fn build_bank(&self, shapes: &ModelShapes, seed: u64) -> anyhow::Result<CompressorBank> {
        self.build_bank_masked(shapes, seed, None)
    }

    /// [`MethodSpec::build_bank`] with optional trained per-layer factor
    /// masks for the selective factorized variants (`factmask:..,mask=sm`
    /// and `factgrass:..,mask=sm`).
    pub fn build_bank_masked(
        &self,
        shapes: &ModelShapes,
        seed: u64,
        trained: Option<&LayerMasks>,
    ) -> anyhow::Result<CompressorBank> {
        use anyhow::{bail, ensure};
        if !self.is_factorized() {
            ensure!(
                shapes.p > 0,
                "flat spec '{}' needs a flat gradient dimension (shapes.p = 0)",
                self.spec_string()
            );
            return Ok(CompressorBank::Flat(self.build(shapes.p, seed)));
        }
        ensure!(
            !shapes.layers.is_empty(),
            "factorized spec '{}' needs hooked layers, but the model exposes none",
            self.spec_string()
        );
        if let Some(masks) = trained {
            ensure!(
                masks.len() == shapes.layers.len(),
                "got trained masks for {} layers, model has {}",
                masks.len(),
                shapes.layers.len()
            );
        }
        let mut cs: Vec<Box<dyn FactorizedCompressor>> =
            Vec::with_capacity(shapes.layers.len());
        for (li, &(d_in, d_out)) in shapes.layers.iter().enumerate() {
            let lseed = rng::hash2(seed, li as u64);
            let c: Box<dyn FactorizedCompressor> = match trained {
                Some(masks) => {
                    let (mi, mo) = &masks[li];
                    let mask_in = mask::RandomMask::from_indices(d_in, mi.clone(), None);
                    let mask_out = mask::RandomMask::from_indices(d_out, mo.clone(), None);
                    match *self {
                        MethodSpec::FactMask { .. } => Box::new(
                            factgrass::FactMask::with_masks(d_in, d_out, mask_in, mask_out),
                        ),
                        MethodSpec::FactGrass { k, .. } => {
                            ensure!(
                                k <= mask_in.output_dim() * mask_out.output_dim(),
                                "spec '{}': k_l = {k} exceeds trained mask dim {}×{} ({li})",
                                self.spec_string(),
                                mask_in.output_dim(),
                                mask_out.output_dim()
                            );
                            Box::new(factgrass::FactGrass::with_masks(
                                d_in, d_out, mask_in, mask_out, k, lseed,
                            ))
                        }
                        _ => bail!(
                            "spec '{}' does not take trained factor masks",
                            self.spec_string()
                        ),
                    }
                }
                None => self.build_factorized(d_in, d_out, lseed)?,
            };
            cs.push(c);
        }
        Ok(CompressorBank::Factored(cs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared check: every compressor is (a) linear, (b) deterministic.
    fn check_linear_deterministic(c: &dyn Compressor) {
        let p = c.input_dim();
        let mut rng = rng::Pcg::new(99);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let ca = c.compress(&a);
        let ca2 = c.compress(&a);
        assert_eq!(ca, ca2, "{} not deterministic", c.name());
        let cb = c.compress(&b);
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let csum = c.compress(&sum);
        for i in 0..c.output_dim() {
            let want = ca[i] + cb[i];
            assert!(
                (csum[i] - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "{} not linear at {i}: {} vs {}",
                c.name(),
                csum[i],
                want
            );
        }
    }

    #[test]
    fn all_methods_linear_and_deterministic() {
        let p = 512;
        let specs = [
            MethodSpec::RandomMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 1 },
            MethodSpec::Sjlt { k: 64, s: 4 },
            MethodSpec::Gauss { k: 64 },
            MethodSpec::Fjlt { k: 64 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        for spec in &specs {
            let c = spec.build(p, 1234);
            assert_eq!(c.input_dim(), p);
            assert_eq!(c.output_dim(), spec.output_dim());
            check_linear_deterministic(c.as_ref());
        }
    }

    #[test]
    fn sparse_compress_matches_dense() {
        let p = 1024;
        let specs = [
            MethodSpec::RandomMask { k: 128 },
            MethodSpec::Sjlt { k: 128, s: 2 },
            MethodSpec::Gauss { k: 32 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        let mut rng = rng::Pcg::new(7);
        // 5% dense input
        let mut idx = vec![];
        let mut vals = vec![];
        let mut dense = vec![0.0f32; p];
        for j in 0..p {
            if rng.next_f32() < 0.05 {
                let v = rng.next_gaussian();
                idx.push(j as u32);
                vals.push(v);
                dense[j] = v;
            }
        }
        for spec in &specs {
            let c = spec.build(p, 555);
            let a = c.compress(&dense);
            let mut b = vec![0.0; c.output_dim()];
            c.compress_sparse_into(&idx, &vals, &mut b);
            for i in 0..a.len() {
                assert!(
                    (a[i] - b[i]).abs() < 1e-4,
                    "{} sparse/dense mismatch at {i}",
                    c.name()
                );
            }
        }
    }

    #[test]
    fn method_spec_string_roundtrip() {
        let specs = [
            MethodSpec::RandomMask { k: 2048 },
            MethodSpec::SelectiveMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 2 },
            MethodSpec::Gauss { k: 8192 },
            MethodSpec::Fjlt { k: 4096 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 512,
                mask: MaskKind::Selective,
            },
        ];
        for spec in specs {
            let back = MethodSpec::parse(&spec.spec_string()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut s = Scratch::new();
        let mut a = s.take_f32(128);
        a[0] = 3.0;
        let ptr = a.as_ptr();
        s.put_f32(a);
        // same-or-smaller request reuses the pooled allocation, zeroed
        let b = s.take_f32(64);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(b.as_ptr(), ptr);
        s.put_f32(b);
        let t = s.take_table(16);
        assert_eq!(t.len(), 16);
        s.put_table(t);
    }

    #[test]
    fn sparse_dispatch_viability_per_kernel() {
        // Only kernels whose dense batch cost scales with the input width
        // opt in to dense→CSR conversion; gather-bound kernels and the
        // densify-and-fallback baselines stay dense.
        let p = 128;
        assert!(MethodSpec::Sjlt { k: 8, s: 1 }.build(p, 1).sparse_dispatch_viable());
        assert!(!MethodSpec::RandomMask { k: 8 }.build(p, 1).sparse_dispatch_viable());
        assert!(!MethodSpec::Gauss { k: 8 }.build(p, 1).sparse_dispatch_viable());
        assert!(!MethodSpec::Fjlt { k: 8 }.build(p, 1).sparse_dispatch_viable());
        let grass = MethodSpec::Grass {
            k: 8,
            k_prime: 32,
            mask: MaskKind::Random,
        };
        assert!(!grass.build(p, 1).sparse_dispatch_viable());
        // Banks: flat delegates; factorized is the AND over layers.
        let shapes = ModelShapes::factored(vec![(32, 16), (16, 32)]);
        let viable = |spec: MethodSpec| {
            spec.build_bank(&shapes, 1).unwrap().sparse_dispatch_viable()
        };
        assert!(viable(MethodSpec::LoGra { k_in: 4, k_out: 4 }));
        assert!(viable(MethodSpec::FactSjlt { k_in: 4, k_out: 4 }));
        assert!(!viable(MethodSpec::FactGrass {
            k: 8,
            k_in: 4,
            k_out: 4,
            mask: MaskKind::Random,
        }));
        assert!(!viable(MethodSpec::FactMask {
            k_in: 4,
            k_out: 4,
            mask: MaskKind::Random,
        }));
        assert!(MethodSpec::Sjlt { k: 8, s: 1 }
            .build_bank(&ModelShapes::flat(p), 1)
            .unwrap()
            .sparse_dispatch_viable());
    }

    #[test]
    fn default_sparse_batch_densifies_and_matches() {
        // Compressors without a tuned CSR kernel (Gauss, FJLT) take the
        // densify-and-fallback default; it must equal the dense batch path.
        let (p, n) = (600, 4);
        let mut rng = rng::Pcg::new(23);
        let gs: Vec<f32> = (0..n * p)
            .map(|_| {
                if rng.next_f32() < 0.9 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect();
        let rows = SparseRows::from_dense_threshold(&gs, n, p, 0.0);
        let mut scratch = Scratch::new();
        for spec in [MethodSpec::Gauss { k: 40 }, MethodSpec::Fjlt { k: 64 }] {
            let c = spec.build(p, 5);
            let k = c.output_dim();
            let mut dense_out = vec![0.0f32; n * k];
            c.compress_batch_with(&gs, n, &mut dense_out, &mut scratch);
            let mut sparse_out = vec![0.0f32; n * k];
            c.compress_sparse_batch_with(&rows, &mut sparse_out, &mut scratch);
            for i in 0..n * k {
                assert!(
                    (dense_out[i] - sparse_out[i]).abs() <= 1e-4 * (1.0 + dense_out[i].abs()),
                    "{} at {i}: {} vs {}",
                    c.name(),
                    sparse_out[i],
                    dense_out[i]
                );
            }
        }
    }

    #[test]
    fn batch_with_scratch_matches_per_sample_for_all_methods() {
        let (p, n) = (700, 5);
        let specs = [
            MethodSpec::RandomMask { k: 96 },
            MethodSpec::Sjlt { k: 96, s: 1 },
            MethodSpec::Sjlt { k: 96, s: 3 },
            MethodSpec::Gauss { k: 48 },
            MethodSpec::Fjlt { k: 96 },
            MethodSpec::Grass {
                k: 48,
                k_prime: 192,
                mask: MaskKind::Random,
            },
        ];
        let mut rng = rng::Pcg::new(17);
        let gs: Vec<f32> = (0..n * p).map(|_| rng.next_gaussian()).collect();
        let mut scratch = Scratch::new();
        for spec in &specs {
            let c = spec.build(p, 77);
            let k = c.output_dim();
            let mut batch = vec![0.0f32; n * k];
            // run twice through the same scratch to exercise buffer reuse
            c.compress_batch_with(&gs, n, &mut batch, &mut scratch);
            c.compress_batch_with(&gs, n, &mut batch, &mut scratch);
            for i in 0..n {
                let single = c.compress(&gs[i * p..(i + 1) * p]);
                for j in 0..k {
                    assert!(
                        (batch[i * k + j] - single[j]).abs() <= 1e-4 * (1.0 + single[j].abs()),
                        "{} row {i} col {j}: {} vs {}",
                        c.name(),
                        batch[i * k + j],
                        single[j]
                    );
                }
            }
        }
    }

    /// Draw a random spec covering every variant (property-test generator).
    fn random_spec(rng: &mut rng::Pcg) -> MethodSpec {
        let k = 1 + rng.next_below(64);
        let k_in = 1 + rng.next_below(16);
        let k_out = 1 + rng.next_below(16);
        let mask = if rng.next_f32() < 0.5 {
            MaskKind::Random
        } else {
            MaskKind::Selective
        };
        match rng.next_below(10) {
            0 => MethodSpec::RandomMask { k },
            1 => MethodSpec::SelectiveMask { k },
            2 => MethodSpec::Sjlt {
                k,
                s: 1 + rng.next_below(k.min(4)),
            },
            3 => MethodSpec::Gauss { k },
            4 => MethodSpec::Fjlt { k },
            5 => MethodSpec::Grass {
                k,
                k_prime: k + rng.next_below(256),
                mask,
            },
            6 => MethodSpec::FactGrass {
                k: 1 + rng.next_below(k_in * k_out),
                k_in,
                k_out,
                mask,
            },
            7 => MethodSpec::LoGra { k_in, k_out },
            8 => MethodSpec::FactSjlt { k_in, k_out },
            _ => MethodSpec::FactMask { k_in, k_out, mask },
        }
    }

    #[test]
    fn method_spec_roundtrip_property() {
        // parse(spec_string(s)) == s for every variant, on 200 random draws.
        let mut rng = rng::Pcg::new(0x5EC5);
        for trial in 0..200 {
            let spec = random_spec(&mut rng);
            let s = spec.spec_string();
            let back = MethodSpec::parse(&s)
                .unwrap_or_else(|e| panic!("trial {trial}: '{s}' failed to parse: {e}"));
            assert_eq!(back, spec, "trial {trial}: '{s}' did not roundtrip");
        }
    }

    #[test]
    fn build_dims_match_output_dim_property() {
        // Flat specs: build(p).output_dim() == spec.output_dim().
        // Factorized specs: every bank member matches layer_output_dim and
        // the bank total matches bank_output_dim.
        let mut rng = rng::Pcg::new(0xD1B5);
        let shapes = ModelShapes::factored(vec![(48, 32), (32, 48), (16, 16)]);
        let p = 512;
        for trial in 0..60 {
            let spec = random_spec(&mut rng);
            if spec.is_factorized() {
                let bank = spec
                    .build_bank(&shapes, 9 + trial as u64)
                    .unwrap_or_else(|e| panic!("trial {trial} ({}): {e}", spec.spec_string()));
                let cs = bank.as_factored().unwrap();
                assert_eq!(cs.len(), shapes.layers.len());
                for (c, &(d_in, d_out)) in cs.iter().zip(&shapes.layers) {
                    assert_eq!(
                        c.output_dim(),
                        spec.layer_output_dim(d_in, d_out).unwrap(),
                        "{} on {d_in}×{d_out}",
                        spec.spec_string()
                    );
                }
                assert_eq!(
                    bank.output_dim(),
                    spec.bank_output_dim(&shapes).unwrap(),
                    "{}",
                    spec.spec_string()
                );
                assert_eq!(bank.layer_dims().iter().sum::<usize>(), bank.output_dim());
            } else {
                let spec = match spec {
                    // keep k' ≤ p for the GraSS draw
                    MethodSpec::Grass { k, k_prime, mask } => MethodSpec::Grass {
                        k,
                        k_prime: k_prime.min(p),
                        mask,
                    },
                    s => s,
                };
                let c = spec.build(p, 7 + trial as u64);
                assert_eq!(c.input_dim(), p, "{}", spec.spec_string());
                assert_eq!(c.output_dim(), spec.output_dim(), "{}", spec.spec_string());
                let bank = spec.build_bank(&ModelShapes::flat(p), 7 + trial as u64).unwrap();
                assert_eq!(bank.output_dim(), spec.output_dim());
                assert!(bank.as_flat().is_some() && !bank.is_factored());
            }
        }
    }

    #[test]
    fn factorized_bank_clamps_and_validates() {
        // kin/kout clamp to the layer shape; the flat/factorized mismatch
        // paths return descriptive errors rather than panicking.
        let spec = MethodSpec::LoGra { k_in: 64, k_out: 64 };
        let bank = spec.build_bank(&ModelShapes::single(16, 8), 1).unwrap();
        assert_eq!(bank.output_dim(), 16 * 8);
        assert!(spec
            .build_bank(&ModelShapes::flat(128), 1)
            .is_err());
        let flat = MethodSpec::Sjlt { k: 8, s: 1 };
        assert!(flat.build_factorized(16, 16, 1).is_err());
        assert!(flat.build_bank(&ModelShapes::flat(0), 1).is_err());
        // FactGraSS with k_l too large for the clamped masked dim errors.
        let fg = MethodSpec::FactGrass {
            k: 200,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        assert!(fg.build_factorized(64, 64, 1).is_err());
    }

    #[test]
    fn bank_construction_is_seed_deterministic() {
        // cache and attribute must reconstruct identical projections.
        let spec = MethodSpec::FactGrass {
            k: 16,
            k_in: 8,
            k_out: 8,
            mask: MaskKind::Random,
        };
        let shapes = ModelShapes::factored(vec![(32, 24), (24, 32)]);
        let b1 = spec.build_bank(&shapes, 77).unwrap();
        let b2 = spec.build_bank(&shapes, 77).unwrap();
        let (c1, c2) = (b1.as_factored().unwrap(), b2.as_factored().unwrap());
        let mut rng = rng::Pcg::new(3);
        let t = 3;
        for (a, b) in c1.iter().zip(c2) {
            let x: Vec<f32> = (0..t * a.d_in()).map(|_| rng.next_gaussian()).collect();
            let dy: Vec<f32> = (0..t * a.d_out()).map(|_| rng.next_gaussian()).collect();
            assert_eq!(a.compress(t, &x, &dy), b.compress(t, &x, &dy));
        }
    }

    #[test]
    fn method_spec_parse_defaults_and_errors() {
        assert_eq!(
            MethodSpec::parse("sjlt:k=64").unwrap(),
            MethodSpec::Sjlt { k: 64, s: 1 }
        );
        assert_eq!(
            MethodSpec::parse("grass:k=8,kp=32").unwrap(),
            MethodSpec::Grass {
                k: 8,
                k_prime: 32,
                mask: MaskKind::Random
            }
        );
        assert!(MethodSpec::parse("bogus:k=1").is_err());
        assert!(MethodSpec::parse("sjlt").is_err());
    }
}
