//! Gradient compressors — the paper's core contribution plus every baseline.
//!
//! All compressors implement [`Compressor`]: a deterministic (seeded) linear
//! map `R^p → R^k` applied to per-sample gradients. The paper's taxonomy:
//!
//! | Name (paper) | Type | Complexity | Here |
//! |---|---|---|---|
//! | `RM_k` (Random Mask) | sparsification | O(k) | [`mask::RandomMask`] |
//! | `SM_k` (Selective Mask) | sparsification | O(k) | [`selective::SelectiveMask`] |
//! | `SJLT_k` | sparse projection | O(p·s) | [`sjlt::Sjlt`] |
//! | `GraSS = SJLT_k ∘ MASK_k'` | two-stage | O(k') | [`grass::Grass`] |
//! | `GAUSS_k` | dense baseline | O(pk) | [`gauss::GaussianProjection`] |
//! | `FJLT_k` | structured baseline | O((p+k)log p) | [`fjlt::Fjlt`] |
//! | `LoGra = GAUSS_{kin⊗kout}` | factorized baseline | O(√(p_l k_l)) | [`logra::LoGra`] |
//! | `FactGraSS = SJLT ∘ MASK_{kin'⊗kout'}` | factorized two-stage | O(k'_l) | [`factgrass::FactGrass`] |
//!
//! The factorized compressors ([`FactorizedCompressor`]) consume the LoGra
//! interface — per-layer inputs `z_in ∈ R^{T×d_in}` and pre-activation
//! gradients `Dz_out ∈ R^{T×d_out}` — and never materialise the full
//! `d_in·d_out` gradient (paper §3.3.2).

pub mod factgrass;
pub mod fjlt;
pub mod gauss;
pub mod grass;
pub mod logra;
pub mod mask;
pub mod rng;
pub mod selective;
pub mod sjlt;

/// Reusable per-worker workspace for the batch compression hot path.
///
/// Every tuned `compress_batch_with` kernel draws its temporaries (masked
/// intermediates, SJLT bucket/sign chunk tables, FWHT padding buffers,
/// factor projections) from here instead of allocating, so a long-running
/// compress worker performs **no steady-state heap allocation**: buffers are
/// taken, used, and returned, and the next batch reuses their capacity.
///
/// One instance belongs to one worker thread — kernels take it `&mut`, so
/// the type system forbids sharing (the pipeline keeps one per compress
/// worker). Kernels that parallelise internally split the scratch-owned
/// buffers into disjoint row ranges for their helper threads.
#[derive(Default)]
pub struct Scratch {
    /// Recycled f32 buffers (best-fit by capacity).
    f32_pool: Vec<Vec<f32>>,
    /// Recycled SJLT (bucket, sign) chunk tables.
    table_pool: Vec<Vec<(u32, f32)>>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a zeroed f32 buffer of exactly `len` elements, reusing pooled
    /// capacity when possible. Return it with [`Scratch::put_f32`].
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        // Best fit: the smallest pooled buffer that already holds `len`, so
        // a small request never consumes (and a later large request never
        // regrows) the pool's biggest allocation.
        let pos = self
            .f32_pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        let mut v = match pos {
            Some(i) => self.f32_pool.swap_remove(i),
            None => self.f32_pool.pop().unwrap_or_default(),
        };
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer taken with [`Scratch::take_f32`] to the pool.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.f32_pool.push(v);
    }

    /// Take a (bucket, sign) table of exactly `len` entries (contents
    /// unspecified — kernels overwrite before reading).
    pub fn take_table(&mut self, len: usize) -> Vec<(u32, f32)> {
        let pos = self
            .table_pool
            .iter()
            .enumerate()
            .filter(|(_, b)| b.capacity() >= len)
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        let mut v = match pos {
            Some(i) => self.table_pool.swap_remove(i),
            None => self.table_pool.pop().unwrap_or_default(),
        };
        v.clear();
        v.resize(len, (0, 0.0));
        v
    }

    /// Return a table taken with [`Scratch::take_table`] to the pool.
    pub fn put_table(&mut self, v: Vec<(u32, f32)>) {
        self.table_pool.push(v);
    }
}

/// A seeded linear compression map `R^p → R^k` over dense gradient vectors.
pub trait Compressor: Send + Sync {
    /// Input dimensionality `p`.
    fn input_dim(&self) -> usize;
    /// Output (compressed) dimensionality `k`.
    fn output_dim(&self) -> usize;

    /// Compress `g` (len = `input_dim`) into `out` (len = `output_dim`).
    /// `out` is fully overwritten.
    fn compress_into(&self, g: &[f32], out: &mut [f32]);

    /// Convenience allocator form.
    fn compress(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(g, &mut out);
        out
    }

    /// Compress `n` rows (`n × p` → `n × k`) with a throwaway workspace.
    /// Callers on the hot path should hold a [`Scratch`] and use
    /// [`Compressor::compress_batch_with`] instead.
    fn compress_batch(&self, gs: &[f32], n: usize, out: &mut [f32]) {
        let mut scratch = Scratch::new();
        self.compress_batch_with(gs, n, out, &mut scratch);
    }

    /// Batch-first entry point: compress `n` rows (`n × p` → `n × k`),
    /// drawing all temporaries from `scratch` so steady-state compression
    /// is allocation-free. The default falls back to a row-parallel loop
    /// over [`Compressor::compress_into`]; every production compressor
    /// overrides it with a tuned kernel that amortises projector setup
    /// across the whole batch (chunked bucket/sign tables for SJLT, blocked
    /// matmul for GAUSS, shared sign/FWHT buffers for FJLT, hoisted mask
    /// intermediates for GraSS).
    fn compress_batch_with(&self, gs: &[f32], n: usize, out: &mut [f32], _scratch: &mut Scratch) {
        let p = self.input_dim();
        let k = self.output_dim();
        assert_eq!(gs.len(), n * p);
        assert_eq!(out.len(), n * k);
        crate::util::par::par_chunks_mut(out, k, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let i = row_start + off;
                self.compress_into(&gs[i * p..(i + 1) * p], orow);
            }
        });
    }

    /// Compress a sparse input given as (indices, values) pairs. The default
    /// densifies; SJLT and masks override with nnz-scaling implementations —
    /// this is the paper's "complexity scales with nnz(g)" property (§3.1).
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        let mut dense = vec![0.0; self.input_dim()];
        for (&i, &v) in idx.iter().zip(vals) {
            dense[i as usize] = v;
        }
        self.compress_into(&dense, out);
    }

    /// Human-readable method name used in experiment reports.
    fn name(&self) -> String;
}

/// A factorized compressor for linear layers: consumes the layer's input
/// activations `x ∈ R^{T×d_in}` (row-major) and pre-activation gradients
/// `dy ∈ R^{T×d_out}` and emits the compressed per-sample gradient of the
/// weight matrix, without materialising the `d_out×d_in` gradient.
pub trait FactorizedCompressor: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;
    /// Compressed dimension `k_l`.
    fn output_dim(&self) -> usize;

    /// `x`: `T × d_in` row-major; `dy`: `T × d_out` row-major.
    /// `out` (len = `output_dim`) is fully overwritten.
    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]);

    fn compress(&self, t: usize, x: &[f32], dy: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(t, x, dy, &mut out);
        out
    }

    /// Batch-first entry point: compress `n` samples at once.
    ///
    /// `x` is `n × t × d_in` row-major, `dy` is `n × t × d_out` row-major.
    /// Sample `i` writes its `output_dim()` values at
    /// `out[i·out_stride + out_off ..]` — the strided layout lets the cache
    /// pipeline hand one `count × k_total` block to a stack of per-layer
    /// compressors, each filling its own column band (`out_stride = k_total`,
    /// `out_off` = the layer's offset). All temporaries come from `scratch`.
    ///
    /// The default loops over [`FactorizedCompressor::compress_into`];
    /// tuned kernels batch the factor projections across all `n·t`
    /// timesteps and hoist the per-sample reconstruction buffers into the
    /// workspace.
    #[allow(clippy::too_many_arguments)]
    fn compress_batch_with(
        &self,
        n: usize,
        t: usize,
        x: &[f32],
        dy: &[f32],
        out: &mut [f32],
        out_stride: usize,
        out_off: usize,
        _scratch: &mut Scratch,
    ) {
        let k = self.output_dim();
        let (d_in, d_out) = (self.d_in(), self.d_out());
        assert_eq!(x.len(), n * t * d_in);
        assert_eq!(dy.len(), n * t * d_out);
        assert_eq!(out.len(), n * out_stride);
        assert!(out_off + k <= out_stride);
        for i in 0..n {
            let base = i * out_stride + out_off;
            self.compress_into(
                t,
                &x[i * t * d_in..(i + 1) * t * d_in],
                &dy[i * t * d_out..(i + 1) * t * d_out],
                &mut out[base..base + k],
            );
        }
    }

    fn name(&self) -> String;
}

/// Which mask flavour a GraSS / FactGraSS instance uses for stage 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskKind {
    Random,
    Selective,
}

/// Compression method selector used by configs and the CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum MethodSpec {
    /// `RM_k`
    RandomMask { k: usize },
    /// `SM_k` (indices must be trained first; falls back to magnitude-free
    /// random selection if no trained mask is available).
    SelectiveMask { k: usize },
    /// `SJLT_k` with `s` non-zeros per column (paper uses s = 1).
    Sjlt { k: usize, s: usize },
    /// `GAUSS_k`
    Gauss { k: usize },
    /// `FJLT_k`
    Fjlt { k: usize },
    /// `GraSS = SJLT_k ∘ MASK_k'`
    Grass {
        k: usize,
        k_prime: usize,
        mask: MaskKind,
    },
}

impl MethodSpec {
    /// Parse a CLI/config spec string, e.g. `rm:k=2048`, `sjlt:k=4096,s=1`,
    /// `gauss:k=2048`, `fjlt:k=8192`, `grass:k=2048,kp=8192,mask=rm`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        use anyhow::{anyhow, bail};
        let (head, rest) = s.split_once(':').unwrap_or((s, ""));
        let mut kv = std::collections::BTreeMap::new();
        for item in rest.split(',').filter(|t| !t.is_empty()) {
            let (k, v) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("bad spec item '{item}' in '{s}'"))?;
            kv.insert(k.trim(), v.trim());
        }
        let need = |key: &str| -> anyhow::Result<usize> {
            kv.get(key)
                .ok_or_else(|| anyhow!("spec '{s}' missing '{key}='"))?
                .parse()
                .map_err(|e| anyhow!("spec '{s}': bad {key}: {e}"))
        };
        Ok(match head {
            "rm" | "random_mask" => MethodSpec::RandomMask { k: need("k")? },
            "sm" | "selective_mask" => MethodSpec::SelectiveMask { k: need("k")? },
            "sjlt" => MethodSpec::Sjlt {
                k: need("k")?,
                s: need("s").unwrap_or(1),
            },
            "gauss" => MethodSpec::Gauss { k: need("k")? },
            "fjlt" => MethodSpec::Fjlt { k: need("k")? },
            "grass" => MethodSpec::Grass {
                k: need("k")?,
                k_prime: need("kp")?,
                mask: match kv.get("mask").copied().unwrap_or("rm") {
                    "rm" => MaskKind::Random,
                    "sm" => MaskKind::Selective,
                    other => bail!("spec '{s}': unknown mask '{other}'"),
                },
            },
            other => bail!("unknown compression method '{other}'"),
        })
    }

    /// Canonical spec string (inverse of [`MethodSpec::parse`]).
    pub fn spec_string(&self) -> String {
        match self {
            MethodSpec::RandomMask { k } => format!("rm:k={k}"),
            MethodSpec::SelectiveMask { k } => format!("sm:k={k}"),
            MethodSpec::Sjlt { k, s } => format!("sjlt:k={k},s={s}"),
            MethodSpec::Gauss { k } => format!("gauss:k={k}"),
            MethodSpec::Fjlt { k } => format!("fjlt:k={k}"),
            MethodSpec::Grass { k, k_prime, mask } => format!(
                "grass:k={k},kp={k_prime},mask={}",
                match mask {
                    MaskKind::Random => "rm",
                    MaskKind::Selective => "sm",
                }
            ),
        }
    }

    pub fn output_dim(&self) -> usize {
        match self {
            MethodSpec::RandomMask { k }
            | MethodSpec::SelectiveMask { k }
            | MethodSpec::Sjlt { k, .. }
            | MethodSpec::Gauss { k }
            | MethodSpec::Fjlt { k }
            | MethodSpec::Grass { k, .. } => *k,
        }
    }

    /// Instantiate the compressor for input dimension `p` and `seed`.
    pub fn build(&self, p: usize, seed: u64) -> Box<dyn Compressor> {
        match *self {
            MethodSpec::RandomMask { k } => Box::new(mask::RandomMask::new(p, k, seed)),
            MethodSpec::SelectiveMask { k } => {
                // Untrained selective mask degenerates to a random mask with a
                // distinct stream; `selective::SelectiveMask::from_scores`
                // builds the trained variant.
                Box::new(mask::RandomMask::new(p, k, rng::hash2(seed, 0x5E1E)))
            }
            MethodSpec::Sjlt { k, s } => Box::new(sjlt::Sjlt::new(p, k, s, seed)),
            MethodSpec::Gauss { k } => Box::new(gauss::GaussianProjection::new(p, k, seed)),
            MethodSpec::Fjlt { k } => Box::new(fjlt::Fjlt::new(p, k, seed)),
            MethodSpec::Grass { k, k_prime, mask } => {
                Box::new(grass::Grass::new(p, k_prime, k, mask, seed))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared check: every compressor is (a) linear, (b) deterministic.
    fn check_linear_deterministic(c: &dyn Compressor) {
        let p = c.input_dim();
        let mut rng = rng::Pcg::new(99);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let ca = c.compress(&a);
        let ca2 = c.compress(&a);
        assert_eq!(ca, ca2, "{} not deterministic", c.name());
        let cb = c.compress(&b);
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let csum = c.compress(&sum);
        for i in 0..c.output_dim() {
            let want = ca[i] + cb[i];
            assert!(
                (csum[i] - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "{} not linear at {i}: {} vs {}",
                c.name(),
                csum[i],
                want
            );
        }
    }

    #[test]
    fn all_methods_linear_and_deterministic() {
        let p = 512;
        let specs = [
            MethodSpec::RandomMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 1 },
            MethodSpec::Sjlt { k: 64, s: 4 },
            MethodSpec::Gauss { k: 64 },
            MethodSpec::Fjlt { k: 64 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        for spec in &specs {
            let c = spec.build(p, 1234);
            assert_eq!(c.input_dim(), p);
            assert_eq!(c.output_dim(), spec.output_dim());
            check_linear_deterministic(c.as_ref());
        }
    }

    #[test]
    fn sparse_compress_matches_dense() {
        let p = 1024;
        let specs = [
            MethodSpec::RandomMask { k: 128 },
            MethodSpec::Sjlt { k: 128, s: 2 },
            MethodSpec::Gauss { k: 32 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        let mut rng = rng::Pcg::new(7);
        // 5% dense input
        let mut idx = vec![];
        let mut vals = vec![];
        let mut dense = vec![0.0f32; p];
        for j in 0..p {
            if rng.next_f32() < 0.05 {
                let v = rng.next_gaussian();
                idx.push(j as u32);
                vals.push(v);
                dense[j] = v;
            }
        }
        for spec in &specs {
            let c = spec.build(p, 555);
            let a = c.compress(&dense);
            let mut b = vec![0.0; c.output_dim()];
            c.compress_sparse_into(&idx, &vals, &mut b);
            for i in 0..a.len() {
                assert!(
                    (a[i] - b[i]).abs() < 1e-4,
                    "{} sparse/dense mismatch at {i}",
                    c.name()
                );
            }
        }
    }

    #[test]
    fn method_spec_string_roundtrip() {
        let specs = [
            MethodSpec::RandomMask { k: 2048 },
            MethodSpec::SelectiveMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 2 },
            MethodSpec::Gauss { k: 8192 },
            MethodSpec::Fjlt { k: 4096 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 512,
                mask: MaskKind::Selective,
            },
        ];
        for spec in specs {
            let back = MethodSpec::parse(&spec.spec_string()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn scratch_reuses_capacity() {
        let mut s = Scratch::new();
        let mut a = s.take_f32(128);
        a[0] = 3.0;
        let ptr = a.as_ptr();
        s.put_f32(a);
        // same-or-smaller request reuses the pooled allocation, zeroed
        let b = s.take_f32(64);
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&v| v == 0.0));
        assert_eq!(b.as_ptr(), ptr);
        s.put_f32(b);
        let t = s.take_table(16);
        assert_eq!(t.len(), 16);
        s.put_table(t);
    }

    #[test]
    fn batch_with_scratch_matches_per_sample_for_all_methods() {
        let (p, n) = (700, 5);
        let specs = [
            MethodSpec::RandomMask { k: 96 },
            MethodSpec::Sjlt { k: 96, s: 1 },
            MethodSpec::Sjlt { k: 96, s: 3 },
            MethodSpec::Gauss { k: 48 },
            MethodSpec::Fjlt { k: 96 },
            MethodSpec::Grass {
                k: 48,
                k_prime: 192,
                mask: MaskKind::Random,
            },
        ];
        let mut rng = rng::Pcg::new(17);
        let gs: Vec<f32> = (0..n * p).map(|_| rng.next_gaussian()).collect();
        let mut scratch = Scratch::new();
        for spec in &specs {
            let c = spec.build(p, 77);
            let k = c.output_dim();
            let mut batch = vec![0.0f32; n * k];
            // run twice through the same scratch to exercise buffer reuse
            c.compress_batch_with(&gs, n, &mut batch, &mut scratch);
            c.compress_batch_with(&gs, n, &mut batch, &mut scratch);
            for i in 0..n {
                let single = c.compress(&gs[i * p..(i + 1) * p]);
                for j in 0..k {
                    assert!(
                        (batch[i * k + j] - single[j]).abs() <= 1e-4 * (1.0 + single[j].abs()),
                        "{} row {i} col {j}: {} vs {}",
                        c.name(),
                        batch[i * k + j],
                        single[j]
                    );
                }
            }
        }
    }

    #[test]
    fn method_spec_parse_defaults_and_errors() {
        assert_eq!(
            MethodSpec::parse("sjlt:k=64").unwrap(),
            MethodSpec::Sjlt { k: 64, s: 1 }
        );
        assert_eq!(
            MethodSpec::parse("grass:k=8,kp=32").unwrap(),
            MethodSpec::Grass {
                k: 8,
                k_prime: 32,
                mask: MaskKind::Random
            }
        );
        assert!(MethodSpec::parse("bogus:k=1").is_err());
        assert!(MethodSpec::parse("sjlt").is_err());
    }
}
