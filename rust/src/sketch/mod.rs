//! Gradient compressors — the paper's core contribution plus every baseline.
//!
//! All compressors implement [`Compressor`]: a deterministic (seeded) linear
//! map `R^p → R^k` applied to per-sample gradients. The paper's taxonomy:
//!
//! | Name (paper) | Type | Complexity | Here |
//! |---|---|---|---|
//! | `RM_k` (Random Mask) | sparsification | O(k) | [`mask::RandomMask`] |
//! | `SM_k` (Selective Mask) | sparsification | O(k) | [`selective::SelectiveMask`] |
//! | `SJLT_k` | sparse projection | O(p·s) | [`sjlt::Sjlt`] |
//! | `GraSS = SJLT_k ∘ MASK_k'` | two-stage | O(k') | [`grass::Grass`] |
//! | `GAUSS_k` | dense baseline | O(pk) | [`gauss::GaussianProjection`] |
//! | `FJLT_k` | structured baseline | O((p+k)log p) | [`fjlt::Fjlt`] |
//! | `LoGra = GAUSS_{kin⊗kout}` | factorized baseline | O(√(p_l k_l)) | [`logra::LoGra`] |
//! | `FactGraSS = SJLT ∘ MASK_{kin'⊗kout'}` | factorized two-stage | O(k'_l) | [`factgrass::FactGrass`] |
//!
//! The factorized compressors ([`FactorizedCompressor`]) consume the LoGra
//! interface — per-layer inputs `z_in ∈ R^{T×d_in}` and pre-activation
//! gradients `Dz_out ∈ R^{T×d_out}` — and never materialise the full
//! `d_in·d_out` gradient (paper §3.3.2).

pub mod factgrass;
pub mod fjlt;
pub mod gauss;
pub mod grass;
pub mod logra;
pub mod mask;
pub mod rng;
pub mod selective;
pub mod sjlt;

/// A seeded linear compression map `R^p → R^k` over dense gradient vectors.
pub trait Compressor: Send + Sync {
    /// Input dimensionality `p`.
    fn input_dim(&self) -> usize;
    /// Output (compressed) dimensionality `k`.
    fn output_dim(&self) -> usize;

    /// Compress `g` (len = `input_dim`) into `out` (len = `output_dim`).
    /// `out` is fully overwritten.
    fn compress_into(&self, g: &[f32], out: &mut [f32]);

    /// Convenience allocator form.
    fn compress(&self, g: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(g, &mut out);
        out
    }

    /// Compress `n` rows (`n × p` → `n × k`). Default parallelises over
    /// rows; GAUSS overrides with a blocked matmul (the hardware-friendly
    /// form the paper's PyTorch baseline uses).
    fn compress_batch(&self, gs: &[f32], n: usize, out: &mut [f32]) {
        let p = self.input_dim();
        let k = self.output_dim();
        assert_eq!(gs.len(), n * p);
        assert_eq!(out.len(), n * k);
        crate::util::par::par_chunks_mut(out, k, 1, |row_start, chunk| {
            for (off, orow) in chunk.chunks_mut(k).enumerate() {
                let i = row_start + off;
                self.compress_into(&gs[i * p..(i + 1) * p], orow);
            }
        });
    }

    /// Compress a sparse input given as (indices, values) pairs. The default
    /// densifies; SJLT and masks override with nnz-scaling implementations —
    /// this is the paper's "complexity scales with nnz(g)" property (§3.1).
    fn compress_sparse_into(&self, idx: &[u32], vals: &[f32], out: &mut [f32]) {
        debug_assert_eq!(idx.len(), vals.len());
        let mut dense = vec![0.0; self.input_dim()];
        for (&i, &v) in idx.iter().zip(vals) {
            dense[i as usize] = v;
        }
        self.compress_into(&dense, out);
    }

    /// Human-readable method name used in experiment reports.
    fn name(&self) -> String;
}

/// A factorized compressor for linear layers: consumes the layer's input
/// activations `x ∈ R^{T×d_in}` (row-major) and pre-activation gradients
/// `dy ∈ R^{T×d_out}` and emits the compressed per-sample gradient of the
/// weight matrix, without materialising the `d_out×d_in` gradient.
pub trait FactorizedCompressor: Send + Sync {
    fn d_in(&self) -> usize;
    fn d_out(&self) -> usize;
    /// Compressed dimension `k_l`.
    fn output_dim(&self) -> usize;

    /// `x`: `T × d_in` row-major; `dy`: `T × d_out` row-major.
    /// `out` (len = `output_dim`) is fully overwritten.
    fn compress_into(&self, t: usize, x: &[f32], dy: &[f32], out: &mut [f32]);

    fn compress(&self, t: usize, x: &[f32], dy: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.output_dim()];
        self.compress_into(t, x, dy, &mut out);
        out
    }

    fn name(&self) -> String;
}

/// Which mask flavour a GraSS / FactGraSS instance uses for stage 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskKind {
    Random,
    Selective,
}

/// Compression method selector used by configs and the CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum MethodSpec {
    /// `RM_k`
    RandomMask { k: usize },
    /// `SM_k` (indices must be trained first; falls back to magnitude-free
    /// random selection if no trained mask is available).
    SelectiveMask { k: usize },
    /// `SJLT_k` with `s` non-zeros per column (paper uses s = 1).
    Sjlt { k: usize, s: usize },
    /// `GAUSS_k`
    Gauss { k: usize },
    /// `FJLT_k`
    Fjlt { k: usize },
    /// `GraSS = SJLT_k ∘ MASK_k'`
    Grass {
        k: usize,
        k_prime: usize,
        mask: MaskKind,
    },
}

impl MethodSpec {
    /// Parse a CLI/config spec string, e.g. `rm:k=2048`, `sjlt:k=4096,s=1`,
    /// `gauss:k=2048`, `fjlt:k=8192`, `grass:k=2048,kp=8192,mask=rm`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        use anyhow::{anyhow, bail};
        let (head, rest) = s.split_once(':').unwrap_or((s, ""));
        let mut kv = std::collections::BTreeMap::new();
        for item in rest.split(',').filter(|t| !t.is_empty()) {
            let (k, v) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("bad spec item '{item}' in '{s}'"))?;
            kv.insert(k.trim(), v.trim());
        }
        let need = |key: &str| -> anyhow::Result<usize> {
            kv.get(key)
                .ok_or_else(|| anyhow!("spec '{s}' missing '{key}='"))?
                .parse()
                .map_err(|e| anyhow!("spec '{s}': bad {key}: {e}"))
        };
        Ok(match head {
            "rm" | "random_mask" => MethodSpec::RandomMask { k: need("k")? },
            "sm" | "selective_mask" => MethodSpec::SelectiveMask { k: need("k")? },
            "sjlt" => MethodSpec::Sjlt {
                k: need("k")?,
                s: need("s").unwrap_or(1),
            },
            "gauss" => MethodSpec::Gauss { k: need("k")? },
            "fjlt" => MethodSpec::Fjlt { k: need("k")? },
            "grass" => MethodSpec::Grass {
                k: need("k")?,
                k_prime: need("kp")?,
                mask: match kv.get("mask").copied().unwrap_or("rm") {
                    "rm" => MaskKind::Random,
                    "sm" => MaskKind::Selective,
                    other => bail!("spec '{s}': unknown mask '{other}'"),
                },
            },
            other => bail!("unknown compression method '{other}'"),
        })
    }

    /// Canonical spec string (inverse of [`MethodSpec::parse`]).
    pub fn spec_string(&self) -> String {
        match self {
            MethodSpec::RandomMask { k } => format!("rm:k={k}"),
            MethodSpec::SelectiveMask { k } => format!("sm:k={k}"),
            MethodSpec::Sjlt { k, s } => format!("sjlt:k={k},s={s}"),
            MethodSpec::Gauss { k } => format!("gauss:k={k}"),
            MethodSpec::Fjlt { k } => format!("fjlt:k={k}"),
            MethodSpec::Grass { k, k_prime, mask } => format!(
                "grass:k={k},kp={k_prime},mask={}",
                match mask {
                    MaskKind::Random => "rm",
                    MaskKind::Selective => "sm",
                }
            ),
        }
    }

    pub fn output_dim(&self) -> usize {
        match self {
            MethodSpec::RandomMask { k }
            | MethodSpec::SelectiveMask { k }
            | MethodSpec::Sjlt { k, .. }
            | MethodSpec::Gauss { k }
            | MethodSpec::Fjlt { k }
            | MethodSpec::Grass { k, .. } => *k,
        }
    }

    /// Instantiate the compressor for input dimension `p` and `seed`.
    pub fn build(&self, p: usize, seed: u64) -> Box<dyn Compressor> {
        match *self {
            MethodSpec::RandomMask { k } => Box::new(mask::RandomMask::new(p, k, seed)),
            MethodSpec::SelectiveMask { k } => {
                // Untrained selective mask degenerates to a random mask with a
                // distinct stream; `selective::SelectiveMask::from_scores`
                // builds the trained variant.
                Box::new(mask::RandomMask::new(p, k, rng::hash2(seed, 0x5E1E)))
            }
            MethodSpec::Sjlt { k, s } => Box::new(sjlt::Sjlt::new(p, k, s, seed)),
            MethodSpec::Gauss { k } => Box::new(gauss::GaussianProjection::new(p, k, seed)),
            MethodSpec::Fjlt { k } => Box::new(fjlt::Fjlt::new(p, k, seed)),
            MethodSpec::Grass { k, k_prime, mask } => {
                Box::new(grass::Grass::new(p, k_prime, k, mask, seed))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared check: every compressor is (a) linear, (b) deterministic.
    fn check_linear_deterministic(c: &dyn Compressor) {
        let p = c.input_dim();
        let mut rng = rng::Pcg::new(99);
        let a: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
        let ca = c.compress(&a);
        let ca2 = c.compress(&a);
        assert_eq!(ca, ca2, "{} not deterministic", c.name());
        let cb = c.compress(&b);
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let csum = c.compress(&sum);
        for i in 0..c.output_dim() {
            let want = ca[i] + cb[i];
            assert!(
                (csum[i] - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "{} not linear at {i}: {} vs {}",
                c.name(),
                csum[i],
                want
            );
        }
    }

    #[test]
    fn all_methods_linear_and_deterministic() {
        let p = 512;
        let specs = [
            MethodSpec::RandomMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 1 },
            MethodSpec::Sjlt { k: 64, s: 4 },
            MethodSpec::Gauss { k: 64 },
            MethodSpec::Fjlt { k: 64 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        for spec in &specs {
            let c = spec.build(p, 1234);
            assert_eq!(c.input_dim(), p);
            assert_eq!(c.output_dim(), spec.output_dim());
            check_linear_deterministic(c.as_ref());
        }
    }

    #[test]
    fn sparse_compress_matches_dense() {
        let p = 1024;
        let specs = [
            MethodSpec::RandomMask { k: 128 },
            MethodSpec::Sjlt { k: 128, s: 2 },
            MethodSpec::Gauss { k: 32 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 256,
                mask: MaskKind::Random,
            },
        ];
        let mut rng = rng::Pcg::new(7);
        // 5% dense input
        let mut idx = vec![];
        let mut vals = vec![];
        let mut dense = vec![0.0f32; p];
        for j in 0..p {
            if rng.next_f32() < 0.05 {
                let v = rng.next_gaussian();
                idx.push(j as u32);
                vals.push(v);
                dense[j] = v;
            }
        }
        for spec in &specs {
            let c = spec.build(p, 555);
            let a = c.compress(&dense);
            let mut b = vec![0.0; c.output_dim()];
            c.compress_sparse_into(&idx, &vals, &mut b);
            for i in 0..a.len() {
                assert!(
                    (a[i] - b[i]).abs() < 1e-4,
                    "{} sparse/dense mismatch at {i}",
                    c.name()
                );
            }
        }
    }

    #[test]
    fn method_spec_string_roundtrip() {
        let specs = [
            MethodSpec::RandomMask { k: 2048 },
            MethodSpec::SelectiveMask { k: 64 },
            MethodSpec::Sjlt { k: 64, s: 2 },
            MethodSpec::Gauss { k: 8192 },
            MethodSpec::Fjlt { k: 4096 },
            MethodSpec::Grass {
                k: 64,
                k_prime: 512,
                mask: MaskKind::Selective,
            },
        ];
        for spec in specs {
            let back = MethodSpec::parse(&spec.spec_string()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn method_spec_parse_defaults_and_errors() {
        assert_eq!(
            MethodSpec::parse("sjlt:k=64").unwrap(),
            MethodSpec::Sjlt { k: 64, s: 1 }
        );
        assert_eq!(
            MethodSpec::parse("grass:k=8,kp=32").unwrap(),
            MethodSpec::Grass {
                k: 8,
                k_prime: 32,
                mask: MaskKind::Random
            }
        );
        assert!(MethodSpec::parse("bogus:k=1").is_err());
        assert!(MethodSpec::parse("sjlt").is_err());
    }
}
