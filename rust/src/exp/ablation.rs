//! Ablations on the design choices DESIGN.md calls out:
//!
//! 1. **GraSS k′ sweep** (§3.3.1): k′ interpolates between pure
//!    sparsification (k′ = k) and vanilla SJLT (k′ = p) — measure both the
//!    GradDot rank fidelity and the compression cost along that axis.
//! 2. **SJLT s sweep** (§3.1): the paper fixes s = 1 for speed; verify the
//!    error/time trade-off that justifies it.
//! 3. **FactGraSS blow-up factor c = k′/k** (§3.3.2): the theoretical
//!    speedup condition is c ≤ √(p_l/k_l); sweep c and find the empirical
//!    crossover vs LoGra.

use super::report::Table;
use crate::attrib::graddot::graddot_scores;
use crate::linalg::stats::spearman;
use crate::models::shapes::ModelShapes;
use crate::sketch::rng::Pcg;
use crate::sketch::{
    grass::Grass, sjlt::Sjlt, Compressor, FactorizedCompressor, MaskKind, MethodSpec,
};
use crate::util::bench;
use anyhow::Result;
use std::time::Duration;

/// Rank fidelity of compressed GradDot vs exact, on synthetic sparse grads.
fn rank_fidelity(c: &dyn Compressor, n: usize, m: usize, seed: u64) -> f64 {
    let p = c.input_dim();
    let k = c.output_dim();
    let mut rng = Pcg::new(seed);
    let mut gen = |rows: usize| -> Vec<f32> {
        (0..rows * p)
            .map(|_| {
                if rng.next_f32() < 0.5 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect()
    };
    let train = gen(n);
    let queries = gen(m);
    let exact = graddot_scores(&train, n, p, &queries, m);
    let mut ctr = vec![0.0f32; n * k];
    c.compress_batch(&train, n, &mut ctr);
    let mut cte = vec![0.0f32; m * k];
    c.compress_batch(&queries, m, &mut cte);
    let approx = graddot_scores(&ctr, n, k, &cte, m);
    let mut rho = 0.0;
    for q in 0..m {
        rho += spearman(&exact[q * n..(q + 1) * n], &approx[q * n..(q + 1) * n]);
    }
    rho / m as f64
}

/// Ablation 1+2: GraSS k′ sweep and SJLT s sweep at fixed (p, k).
pub fn run_grass_kprime(p: usize, k: usize, out_json: Option<&str>) -> Result<Table> {
    let mut table = Table::new(
        &format!("Ablation — GraSS k′ sweep and SJLT s sweep (p = {p}, k = {k})"),
        &["config", "rank ρ", "time/vec"],
    );
    let (n, m) = (48, 4);
    let mut rng = Pcg::new(3);
    let g: Vec<f32> = (0..p).map(|_| rng.next_gaussian()).collect();
    let mut out = vec![0.0f32; k];

    // k' sweep: k, 2k, 4k, 16k, p
    let mut kps = vec![k, 2 * k, 4 * k, 16 * k, p];
    kps.retain(|&v| v <= p);
    kps.dedup();
    for kp in kps {
        let c = Grass::new(p, kp, k, MaskKind::Random, 7);
        let rho = rank_fidelity(&c, n, m, 11);
        let r = bench::bench_with_budget("kp", Duration::from_millis(60), || {
            c.compress_into(&g, &mut out)
        });
        table.row(vec![
            format!("GraSS k'={kp}"),
            format!("{rho:.4}"),
            super::report::fmt_secs(r.median_secs()),
        ]);
    }
    for s in [1usize, 2, 4, 8] {
        let c = Sjlt::new(p, k, s, 7);
        let rho = rank_fidelity(&c, n, m, 13);
        let r = bench::bench_with_budget("s", Duration::from_millis(60), || {
            c.compress_into(&g, &mut out)
        });
        table.row(vec![
            format!("SJLT s={s}"),
            format!("{rho:.4}"),
            super::report::fmt_secs(r.median_secs()),
        ]);
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok(table)
}

/// Ablation 3: FactGraSS blow-up factor crossover vs LoGra on one
/// Llama-sized layer.
pub fn run_factgrass_blowup(out_json: Option<&str>) -> Result<Table> {
    let (d_in, d_out, t) = (4096usize, 4096usize, 32usize);
    let k_side = 16usize; // k_l = 256
    let kl = k_side * k_side;
    let mut rng = Pcg::new(9);
    let x: Vec<f32> = (0..t * d_in).map(|_| rng.next_gaussian()).collect();
    let dy: Vec<f32> = (0..t * d_out).map(|_| rng.next_gaussian()).collect();
    let mut table = Table::new(
        &format!(
            "Ablation — FactGraSS blow-up factor c (layer {d_in}×{d_out}, k_l = {kl}); \
             theory: faster than LoGra while c ≤ √(p_l/k_l) = {:.0}",
            ((d_in * d_out) as f64 / kl as f64).sqrt()
        ),
        &["method", "c = k'/k", "time/sample"],
    );
    // Single-layer banks through the declarative spec (the only factorized
    // construction path).
    let layer = ModelShapes::single(d_in, d_out);
    let build = |spec: MethodSpec| -> Box<dyn FactorizedCompressor> {
        spec.build_bank(&layer, 2)
            .expect("ablation bank construction")
            .into_factored()
            .expect("factorized spec builds a factored bank")
            .remove(0)
    };
    let lg = build(MethodSpec::LoGra {
        k_in: k_side,
        k_out: k_side,
    });
    let mut out = vec![0.0f32; kl];
    let r = bench::bench_with_budget("logra", Duration::from_millis(120), || {
        lg.compress_into(t, &x, &dy, &mut out)
    });
    table.row(vec![
        "LoGra".into(),
        "—".into(),
        super::report::fmt_secs(r.median_secs()),
    ]);
    for mult in [1usize, 2, 4, 8, 16, 32] {
        let side = (mult * k_side).min(d_in);
        let fg = build(MethodSpec::FactGrass {
            k: kl,
            k_in: side,
            k_out: side,
            mask: MaskKind::Random,
        });
        let c = (side * side) as f64 / kl as f64;
        let r = bench::bench_with_budget("fg", Duration::from_millis(120), || {
            fg.compress_into(t, &x, &dy, &mut out)
        });
        table.row(vec![
            format!("FactGraSS {side}⊗{side}"),
            format!("{c:.0}"),
            super::report::fmt_secs(r.median_secs()),
        ]);
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kprime_fidelity_increases_with_kprime() {
        let (p, k) = (2048, 64);
        let lo = rank_fidelity(&Grass::new(p, k, k, MaskKind::Random, 1), 32, 3, 5);
        let hi = rank_fidelity(&Grass::new(p, p, k, MaskKind::Random, 1), 32, 3, 5);
        // k' = p (vanilla SJLT) should beat k' = k (pure mask) on fidelity.
        assert!(
            hi > lo - 0.05,
            "fidelity should not degrade with k': lo={lo:.3} hi={hi:.3}"
        );
    }

    #[test]
    fn ablation_tables_render() {
        let t = run_grass_kprime(1024, 32, None).unwrap();
        assert!(t.rows.len() >= 6);
        // fidelity column parses as f64
        for row in &t.rows {
            let rho: f64 = row[1].parse().unwrap();
            assert!((-1.0..=1.0).contains(&rho));
        }
    }
}
