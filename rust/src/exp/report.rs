//! Plain-text + JSON experiment reports (the "rows the paper prints").

use crate::util::json::Json;
use anyhow::Result;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Append to a results JSON file (list of tables).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut list = if path.exists() {
            match Json::parse(&std::fs::read_to_string(path)?)? {
                Json::Arr(a) => a,
                other => vec![other],
            }
        } else {
            vec![]
        };
        list.push(self.to_json());
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, Json::Arr(list).to_string_pretty())?;
        Ok(())
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}")
    } else {
        format!("{s:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "LDS", "time"]);
        t.row(vec!["SJLT_64".into(), "0.41".into(), "0.5".into()]);
        t.row(vec!["G".into(), "0.4".into(), "10".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("SJLT_64"));
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines.len() >= 5);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_wrong_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn save_appends() {
        let dir = std::env::temp_dir().join(format!("grass_rep_{}", std::process::id()));
        let path = dir.join("results.json");
        let _ = std::fs::remove_file(&path);
        let mut t = Table::new("t1", &["a"]);
        t.row(vec!["1".into()]);
        t.save(&path).unwrap();
        t.save(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
