//! Table 1 — quantitative accuracy (LDS) and compression wall-time.
//!
//! (a) MLP + synthetic digits, TRAK           — `run_table1a`
//! (b) ResNet-lite + synthetic CIFAR2, TRAK   — `run_table1b` (adds GraSS)
//! (c) music transformer + events, TRAK      — `run_table1c`
//! (d) GPT2-tiny + themed corpus, block-diag FIM influence with factorized
//!     compression (RM⊗ / SM⊗ / SJLT⊗ / FactGraSS / LoGra) — `run_table1d`
//!
//! The LDS ground truth (subset retraining through the model's HLO
//! train-step) is computed once per model and shared across methods, as in
//! the paper. Damping is grid-searched on 10% of test and LDS reported on
//! the remaining 90% (App. B.2).

use super::report::{fmt_secs, Table};
use crate::attrib::blockwise::{BlockLayout, BlockwiseEngine};
use crate::attrib::fim::accumulate_fim;
use crate::attrib::influence::{scores_query_side, DAMPING_GRID};
use crate::config::ExpConfig;
use crate::data::{corpus::MusicEvents, corpus::ThemedCorpus, images::SynthCifar2, images::SynthDigits};
use crate::eval::retrain::{TaskData, Trainer};
use crate::eval::{lds_score, sample_subsets};
use crate::runtime::{Arg, Runtime};
use crate::sketch::selective::{
    train_factorized_selective_mask, train_selective_mask, SelectiveMaskConfig, TrainedMask,
};
use crate::sketch::{Compressor, FactorizedCompressor, MaskKind, MethodSpec};
use anyhow::Result;
use std::time::Instant;

/// Shared LDS ground truth for one model/dataset pair.
pub struct GroundTruth {
    pub subsets: Vec<Vec<usize>>,
    /// S × m per-test losses of the retrained subset models.
    pub subset_losses: Vec<f32>,
}

pub fn build_ground_truth(
    trainer: &Trainer,
    train: &TaskData,
    test: &TaskData,
    cfg: &ExpConfig,
) -> Result<GroundTruth> {
    let n = train.len();
    let m = test.len();
    let subsets = sample_subsets(n, cfg.subsets, cfg.subset_frac, cfg.seed ^ 0x11D5);
    let mut subset_losses = Vec::with_capacity(cfg.subsets * m);
    let test_idx: Vec<usize> = (0..m).collect();
    for (s, subset) in subsets.iter().enumerate() {
        let init = trainer.init((cfg.seed as i32) ^ (s as i32 + 1))?;
        let params = trainer.train(init, train, subset, cfg.epochs, cfg.lr, cfg.seed + s as u64)?;
        let losses = trainer.losses(&params, test, &test_idx)?;
        subset_losses.extend_from_slice(&losses);
        eprintln!("  [gt] subset {}/{} retrained", s + 1, cfg.subsets);
    }
    Ok(GroundTruth {
        subsets,
        subset_losses,
    })
}

/// Split tests into (val, eval) index sets — 10% / 90% (at least 1 val).
fn val_split(m: usize) -> (Vec<usize>, Vec<usize>) {
    let v = (m / 10).max(1);
    ((0..v).collect(), (v..m).collect())
}

/// LDS against a subset of the test columns.
fn lds_on(
    scores: &[f32],
    n: usize,
    m: usize,
    gt: &GroundTruth,
    cols: &[usize],
) -> f64 {
    // Restrict scores and losses to the selected test columns.
    let mm = cols.len();
    let mut s2 = vec![0.0f32; mm * n];
    for (new_q, &q) in cols.iter().enumerate() {
        s2[new_q * n..(new_q + 1) * n].copy_from_slice(&scores[q * n..(q + 1) * n]);
    }
    let s_count = gt.subsets.len();
    let mut l2 = vec![0.0f32; s_count * mm];
    for s in 0..s_count {
        for (new_q, &q) in cols.iter().enumerate() {
            l2[s * mm + new_q] = gt.subset_losses[s * m + q];
        }
    }
    lds_score(&s2, n, mm, &gt.subsets, &l2).0
}

/// One TRAK-family experiment: compress per checkpoint, ensemble scores,
/// grid-search damping on the val split, report LDS on the eval split.
#[allow(clippy::too_many_arguments)]
fn eval_method_trak(
    compressed: &[(Vec<f32>, Vec<f32>)], // per checkpoint (train n×k, test m×k)
    n: usize,
    m: usize,
    k: usize,
    gt: &GroundTruth,
) -> Result<(f64, f64)> {
    let (val, evl) = val_split(m);
    // cache FIM per checkpoint
    let fims: Vec<Vec<f32>> = compressed
        .iter()
        .map(|(tr, _)| accumulate_fim(tr, n, k))
        .collect();
    // Damping grid in parallel — each λ needs its own Cholesky (O(k³)),
    // and the factorizations are independent (§Perf iteration 2: the grid
    // was the single-threaded tail of every Table 1 run).
    let grid_vals: Vec<Option<f64>> =
        crate::util::par::par_map_ranges(DAMPING_GRID.len(), 1, |range| {
            range
                .map(|di| {
                    let damping = DAMPING_GRID[di];
                    let mut total = vec![0.0f64; m * n];
                    for (ck, (tr, te)) in compressed.iter().enumerate() {
                        match scores_query_side(&fims[ck], k, damping, tr, n, te, m) {
                            Ok(s) => {
                                for (t, &v) in total.iter_mut().zip(&s) {
                                    *t += v as f64;
                                }
                            }
                            Err(_) => return None,
                        }
                    }
                    let scores: Vec<f32> = total.iter().map(|&v| v as f32).collect();
                    Some(lds_on(&scores, n, m, gt, &val))
                })
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    let mut best = (DAMPING_GRID[0], f64::NEG_INFINITY);
    for (di, v) in grid_vals.iter().enumerate() {
        if let Some(v) = v {
            if *v > best.1 {
                best = (DAMPING_GRID[di], *v);
            }
        }
    }
    // final scores at best damping on eval split
    let mut total = vec![0.0f64; m * n];
    for (ck, (tr, te)) in compressed.iter().enumerate() {
        let s = scores_query_side(&fims[ck], k, best.0, tr, n, te, m)?;
        for (t, &v) in total.iter_mut().zip(&s) {
            *t += v as f64;
        }
    }
    let scores: Vec<f32> = total.iter().map(|&v| v as f32).collect();
    Ok((lds_on(&scores, n, m, gt, &evl), best.0))
}

/// The method lineup for a TRAK table.
fn trak_methods(p: usize, ks: &[usize], include_grass: bool) -> Vec<(String, MethodSpec)> {
    let mut out = vec![];
    for &k in ks {
        out.push((format!("RM_{k}"), MethodSpec::RandomMask { k }));
        out.push((format!("SM_{k}"), MethodSpec::SelectiveMask { k }));
        out.push((format!("SJLT_{k}"), MethodSpec::Sjlt { k, s: 1 }));
        if include_grass {
            let kp = (4 * ks[ks.len() - 1]).min(p);
            out.push((
                format!("GraSS[SJLT_{k}∘RM_{kp}]"),
                MethodSpec::Grass {
                    k,
                    k_prime: kp,
                    mask: MaskKind::Random,
                },
            ));
        }
        out.push((format!("FJLT_{k}"), MethodSpec::Fjlt { k }));
        out.push((format!("GAUSS_{k}"), MethodSpec::Gauss { k }));
    }
    out
}

/// Generic TRAK table runner (Tables 1a–c).
pub fn run_trak_table(
    rt: &Runtime,
    model: &str,
    train: &TaskData,
    test: &TaskData,
    cfg: &ExpConfig,
    include_grass: bool,
    title: &str,
) -> Result<Table> {
    let trainer = Trainer::new(rt, model)?;
    let n = train.len();
    let m = test.len();
    let p = trainer.p;
    eprintln!("[{title}] ground truth: {} subset retrains", cfg.subsets);
    let gt = build_ground_truth(&trainer, train, test, cfg)?;

    // Per-checkpoint raw gradients (one checkpoint in memory at a time).
    let all_train: Vec<usize> = (0..n).collect();
    let all_test: Vec<usize> = (0..m).collect();
    let methods = trak_methods(p, &cfg.ks, include_grass);
    // compressed[method] -> per checkpoint (train, test)
    let mut compressed: Vec<Vec<(Vec<f32>, Vec<f32>)>> = vec![vec![]; methods.len()];
    let mut times = vec![0.0f64; methods.len()];
    // Selective scores are trained once on the first checkpoint's gradients;
    // `MethodSpec::build_with_scores` extracts the per-k top-k masks.
    let mut sm_scores: Option<TrainedMask> = None;

    for ck in 0..cfg.checkpoints {
        eprintln!("[{title}] checkpoint {}/{}", ck + 1, cfg.checkpoints);
        let init = trainer.init(1000 + ck as i32)?;
        let params = trainer.train(
            init,
            train,
            &all_train,
            cfg.epochs,
            cfg.lr,
            cfg.seed ^ (0xC0 + ck as u64),
        )?;
        let g_train = trainer.grads(&params, train, &all_train)?;
        let g_test = trainer.grads(&params, test, &all_test)?;

        if ck == 0 {
            // Train SM scores once (on a gradient subsample, paper §3.2);
            // every per-k mask is a top-k extraction of the same scores.
            let sub_n = n.min(96);
            let sub_m = m.min(8);
            sm_scores = Some(train_selective_mask(
                &g_train[..sub_n * p],
                &g_test[..sub_m * p],
                sub_n,
                sub_m,
                p,
                &SelectiveMaskConfig {
                    steps: 25,
                    seed: cfg.seed,
                    ..Default::default()
                },
            ));
        }

        let scores = &sm_scores.as_ref().expect("trained on checkpoint 0").scores;
        for (mi, (_, spec)) in methods.iter().enumerate() {
            let c: Box<dyn Compressor> = spec.build_with_scores(p, cfg.seed ^ 0x7A8, scores);
            let k = c.output_dim();
            let t0 = Instant::now();
            let mut ctr = vec![0.0f32; n * k];
            c.compress_batch(&g_train, n, &mut ctr);
            let mut cte = vec![0.0f32; m * k];
            c.compress_batch(&g_test, m, &mut cte);
            times[mi] += t0.elapsed().as_secs_f64();
            compressed[mi].push((ctr, cte));
        }
    }

    let mut table = Table::new(title, &["method", "k", "LDS", "time (s)", "damping"]);
    for (mi, (name, spec)) in methods.iter().enumerate() {
        let k = spec.output_dim();
        let (lds, damping) = eval_method_trak(&compressed[mi], n, m, k, &gt)?;
        table.row(vec![
            name.clone(),
            k.to_string(),
            format!("{lds:.4}"),
            fmt_secs(times[mi]),
            format!("{damping:.0e}"),
        ]);
        eprintln!("[{title}] {name}: LDS {lds:.4}, {:.3}s", times[mi]);
    }
    Ok(table)
}

pub fn run_table1a(rt: &Runtime, cfg: &ExpConfig) -> Result<Table> {
    let train = SynthDigits::generate(cfg.n_train, cfg.seed);
    let test = SynthDigits::generate(cfg.n_test, cfg.seed ^ TEST_SALT);
    run_trak_table(
        rt,
        "mlp",
        &TaskData::Labelled(&train),
        &TaskData::Labelled(&test),
        cfg,
        false,
        "Table 1a — MLP (synthetic digits), TRAK",
    )
}

pub fn run_table1b(rt: &Runtime, cfg: &ExpConfig) -> Result<Table> {
    let train = SynthCifar2::generate(cfg.n_train, cfg.seed);
    let test = SynthCifar2::generate(cfg.n_test, cfg.seed ^ TEST_SALT);
    run_trak_table(
        rt,
        "resnet_lite",
        &TaskData::Labelled(&train),
        &TaskData::Labelled(&test),
        cfg,
        true,
        "Table 1b — ResNet-lite (synthetic CIFAR2), TRAK",
    )
}

pub fn run_table1c(rt: &Runtime, cfg: &ExpConfig) -> Result<Table> {
    let seq = rt.manifest.model("music")?.seq.unwrap();
    let train = MusicEvents::generate(cfg.n_train, seq, cfg.seed);
    let test = MusicEvents::generate(cfg.n_test, seq, cfg.seed ^ TEST_SALT);
    run_trak_table(
        rt,
        "music",
        &TaskData::Sequences(&train),
        &TaskData::Sequences(&test),
        cfg,
        true,
        "Table 1c — music transformer (synthetic events), TRAK",
    )
}

const TEST_SALT: u64 = 0x7E57;

// ---------------------------------------------------------------------------
// Table 1d — factorized methods on GPT2-tiny with block-diagonal FIM
// ---------------------------------------------------------------------------

/// Per-layer hooks for a sample set: layers[l] = (xs n×T×d_in, dys n×T×d_out).
pub struct Hooks {
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
    pub n: usize,
    pub seq: usize,
}

/// Collect LoGra hooks for `idx` through the `<model>_hooks` executable.
pub fn collect_hooks(
    rt: &Runtime,
    model: &str,
    params: &[f32],
    data: &crate::data::Sequences,
    idx: &[usize],
) -> Result<Hooks> {
    let meta = rt.manifest.model(model)?.clone();
    let b = rt.manifest.batch_size("hooks", model)?;
    let seq = meta.seq.unwrap();
    let exe = rt.executable(&format!("{model}_hooks"))?;
    let l = meta.layers.len();
    let n = idx.len();
    let mut layers: Vec<(Vec<f32>, Vec<f32>)> = meta
        .layers
        .iter()
        .map(|lm| {
            (
                Vec::with_capacity(n * seq * lm.d_in),
                Vec::with_capacity(n * seq * lm.d_out),
            )
        })
        .collect();
    for chunk in idx.chunks(b) {
        let toks = data.gather(chunk, b);
        let outs = exe.run(&[
            Arg::F32(params.to_vec(), vec![meta.p]),
            Arg::I32(toks, vec![b, seq]),
        ])?;
        for li in 0..l {
            let d_in = meta.layers[li].d_in;
            let d_out = meta.layers[li].d_out;
            layers[li]
                .0
                .extend_from_slice(&outs[li].data[..chunk.len() * seq * d_in]);
            layers[li]
                .1
                .extend_from_slice(&outs[l + li].data[..chunk.len() * seq * d_out]);
        }
    }
    Ok(Hooks { layers, n, seq })
}

/// Compress all samples' hooks through a per-layer compressor bank;
/// returns (n × Σk_l concatenated matrix, wall-time seconds).
pub fn compress_hooks(
    hooks: &Hooks,
    banks: &[Box<dyn FactorizedCompressor>],
) -> (Vec<f32>, f64) {
    let n = hooks.n;
    let seq = hooks.seq;
    let total: usize = banks.iter().map(|b| b.output_dim()).sum();
    let mut out = vec![0.0f32; n * total];
    let t0 = Instant::now();
    crate::util::par::par_chunks_mut(&mut out, total, 1, |row_start, chunk| {
        for (off, orow) in chunk.chunks_mut(total).enumerate() {
            let i = row_start + off;
            let mut pos = 0usize;
            for (li, bank) in banks.iter().enumerate() {
                let (xs, dys) = &hooks.layers[li];
                let d_in = bank.d_in();
                let d_out = bank.d_out();
                let kl = bank.output_dim();
                bank.compress_into(
                    seq,
                    &xs[i * seq * d_in..(i + 1) * seq * d_in],
                    &dys[i * seq * d_out..(i + 1) * seq * d_out],
                    &mut orow[pos..pos + kl],
                );
                pos += kl;
            }
        }
    });
    (out, t0.elapsed().as_secs_f64())
}

/// Sequence-pooled (summed over T) per-layer activations for SM training.
fn pool_hooks(hooks: &Hooks, li: usize, d_in: usize, d_out: usize) -> (Vec<f32>, Vec<f32>) {
    let (xs, dys) = &hooks.layers[li];
    let (n, seq) = (hooks.n, hooks.seq);
    let mut px = vec![0.0f32; n * d_in];
    let mut pd = vec![0.0f32; n * d_out];
    for i in 0..n {
        for t in 0..seq {
            for j in 0..d_in {
                px[i * d_in + j] += xs[(i * seq + t) * d_in + j];
            }
            for j in 0..d_out {
                pd[i * d_out + j] += dys[(i * seq + t) * d_out + j];
            }
        }
    }
    (px, pd)
}

pub fn run_table1d(rt: &Runtime, cfg: &ExpConfig) -> Result<Table> {
    let model = "gpt2_tiny";
    let meta = rt.manifest.model(model)?.clone();
    let seq = meta.seq.unwrap();
    let train = ThemedCorpus::generate(cfg.n_train, seq, cfg.seed);
    let test = ThemedCorpus::generate(cfg.n_test, seq, cfg.seed ^ 0x7E57);
    let trainer = Trainer::new(rt, model)?;
    let n = train.n;
    let m = test.n;

    eprintln!("[table1d] ground truth: {} subset retrains", cfg.subsets);
    let gt = build_ground_truth(
        &trainer,
        &TaskData::Sequences(&train),
        &TaskData::Sequences(&test),
        cfg,
    )?;

    // Base model + hooks.
    let init = trainer.init(2000)?;
    let all: Vec<usize> = (0..n).collect();
    let params = trainer.train(
        init,
        &TaskData::Sequences(&train),
        &all,
        cfg.epochs,
        cfg.lr,
        cfg.seed ^ 0x1D,
    )?;
    eprintln!("[table1d] collecting hooks for {n} train + {m} test samples");
    let hooks_train = collect_hooks(rt, model, &params, &train, &all)?;
    let test_idx: Vec<usize> = (0..m).collect();
    let hooks_test = collect_hooks(rt, model, &params, &test, &test_idx)?;

    let mut table = Table::new(
        "Table 1d — GPT2-tiny (themed corpus), block-diag FIM influence",
        &["method", "k_l", "LDS", "time (s)", "damping"],
    );

    // Per-layer k_l values (paper: k_l ∈ {256, 1024, 4096} at d=768 scale;
    // ours scale to d=128). All construction goes through
    // `MethodSpec::build_bank(_masked)` — the declarative specs below are
    // the whole method lineup.
    let shapes = meta.shapes();
    for &kl in &cfg.ks {
        let k_side = (kl as f64).sqrt() as usize;
        assert_eq!(k_side * k_side, kl, "k_l must be a perfect square");
        // SM masks per layer trained on pooled hooks (factorized Eq. 1).
        let sub_n = n.min(64);
        let sub_m = m.min(8);
        let sm_masks: Vec<(Vec<u32>, Vec<u32>)> = (0..meta.layers.len())
            .map(|li| {
                let lm = &meta.layers[li];
                let (px, pd) = pool_hooks(&hooks_train, li, lm.d_in, lm.d_out);
                let (qx, qd) = pool_hooks(&hooks_test, li, lm.d_in, lm.d_out);
                let (tin, tout) = train_factorized_selective_mask(
                    &px[..sub_n * lm.d_in],
                    &pd[..sub_n * lm.d_out],
                    &qx[..sub_m * lm.d_in],
                    &qd[..sub_m * lm.d_out],
                    sub_n,
                    sub_m,
                    lm.d_in,
                    lm.d_out,
                    &SelectiveMaskConfig {
                        steps: 20,
                        seed: cfg.seed ^ li as u64,
                        ..Default::default()
                    },
                );
                (tin.top_k_indices(k_side), tout.top_k_indices(k_side))
            })
            .collect();

        // (display name, declarative spec, optional trained factor masks)
        type MethodRow<'a> = (String, MethodSpec, Option<&'a [(Vec<u32>, Vec<u32>)]>);
        let methods: Vec<MethodRow> = vec![
            (
                format!("RM_{k_side}⊗{k_side}"),
                MethodSpec::FactMask {
                    k_in: k_side,
                    k_out: k_side,
                    mask: MaskKind::Random,
                },
                None,
            ),
            (
                format!("SM_{k_side}⊗{k_side}"),
                MethodSpec::FactMask {
                    k_in: k_side,
                    k_out: k_side,
                    mask: MaskKind::Selective,
                },
                Some(&sm_masks),
            ),
            (
                format!("SJLT_{k_side}⊗{k_side}"),
                MethodSpec::FactSjlt {
                    k_in: k_side,
                    k_out: k_side,
                },
                None,
            ),
            (
                format!("FactGraSS[SJLT_{kl}∘RM_{}⊗{}]", 2 * k_side, 2 * k_side),
                MethodSpec::FactGrass {
                    k: kl,
                    k_in: 2 * k_side,
                    k_out: 2 * k_side,
                    mask: MaskKind::Random,
                },
                None,
            ),
            (
                format!("LoGra[GAUSS_{k_side}⊗{k_side}]"),
                MethodSpec::LoGra {
                    k_in: k_side,
                    k_out: k_side,
                },
                None,
            ),
        ];

        for (name, mspec, masks) in &methods {
            let bank = mspec.build_bank_masked(&shapes, cfg.seed ^ 0x1D7, *masks)?;
            let banks = bank.as_factored().expect("factorized spec builds a factored bank");
            let dims = bank.layer_dims();
            let (ctr, t1) = compress_hooks(&hooks_train, banks);
            let (cte, t2) = compress_hooks(&hooks_test, banks);
            let layout = BlockLayout::new(dims);
            // damping grid on val split, report on eval split
            let (val, evl) = val_split(m);
            let mut best = (DAMPING_GRID[0], f64::NEG_INFINITY);
            for &damping in DAMPING_GRID {
                let engine = BlockwiseEngine::new(layout.clone(), damping);
                if let Ok(scores) = engine.attribute(&ctr, n, &cte, m) {
                    let v = lds_on(&scores, n, m, &gt, &val);
                    if v > best.1 {
                        best = (damping, v);
                    }
                }
            }
            let engine = BlockwiseEngine::new(layout.clone(), best.0);
            let scores = engine.attribute(&ctr, n, &cte, m)?;
            let lds = lds_on(&scores, n, m, &gt, &evl);
            table.row(vec![
                name.clone(),
                kl.to_string(),
                format!("{lds:.4}"),
                fmt_secs(t1 + t2),
                format!("{:.0e}", best.0),
            ]);
            eprintln!("[table1d] {name} k_l={kl}: LDS {lds:.4}, {:.3}s", t1 + t2);
        }
    }
    Ok(table)
}
