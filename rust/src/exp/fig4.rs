//! Figure 4: projection method micro-benchmark at p = 131072.
//!
//! For each method (dense Gaussian, dense Rademacher, FJLT, SJLT s=1) and
//! each input sparsity level (0%, 90%, 99% zeros), measure per-projection
//! wall time across target dimensions k, plus the relative pairwise-distance
//! error. The paper's shape to reproduce: SJLT time is ~independent of k
//! and scales with nnz; Gauss scales with k·p and ignores sparsity; FJLT is
//! flat in k but cannot exploit sparsity.

use super::report::Table;
use crate::sketch::gauss::GaussianProjection;
use crate::sketch::rng::Pcg;
use crate::sketch::{Compressor, MethodSpec};
use crate::util::bench;
use anyhow::Result;
use std::time::Duration;

pub const FIG4_P: usize = 131_072;
pub const SPARSITY_LEVELS: &[f64] = &[0.0, 0.9, 0.99];

/// Generate a batch of vectors with the requested zero fraction.
fn make_inputs(p: usize, n: usize, zero_frac: f64, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg::new(seed);
    (0..n)
        .map(|_| {
            (0..p)
                .map(|_| {
                    if rng.next_f64() < zero_frac {
                        0.0
                    } else {
                        rng.next_gaussian()
                    }
                })
                .collect()
        })
        .collect()
}

/// Sparse (idx, vals) view of a dense vector.
fn sparse_view(g: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let mut idx = vec![];
    let mut vals = vec![];
    for (j, &v) in g.iter().enumerate() {
        if v != 0.0 {
            idx.push(j as u32);
            vals.push(v);
        }
    }
    (idx, vals)
}

/// Relative pairwise-distance error over a set of compressed vectors.
pub fn relative_distance_error(xs: &[Vec<f32>], cs: &[Vec<f32>]) -> f64 {
    let norm = |v: &[f32]| v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let mut errs = vec![];
    for i in 0..xs.len() {
        for j in (i + 1)..xs.len() {
            let d: Vec<f32> = xs[i].iter().zip(&xs[j]).map(|(a, b)| a - b).collect();
            let dc: Vec<f32> = cs[i].iter().zip(&cs[j]).map(|(a, b)| a - b).collect();
            let (nd, ndc) = (norm(&d), norm(&dc));
            if nd > 1e-12 {
                errs.push(((ndc - nd) / nd).abs());
            }
        }
    }
    errs.iter().sum::<f64>() / errs.len().max(1) as f64
}

pub fn run(ks: &[usize], budget_ms: u64, out_json: Option<&str>) -> Result<Table> {
    let p = FIG4_P;
    // The dense baseline uses Rademacher (±1) entries — the paper's own
    // Figure 1 dense projection. Gaussian entries are JL-equivalent but
    // ~20× more expensive to *generate* on the fly (Box–Muller), which
    // would only widen the dense baseline's gap; at p = 131072 the matrix
    // (k·p·4 B, up to 4.3 GB) cannot be materialised — the paper's own
    // footnote 4 observation.
    type Build = fn(usize, usize) -> Box<dyn Compressor>;
    let methods: Vec<(&str, Build)> = vec![
        ("SJLT(s=1)", |p, k| MethodSpec::Sjlt { k, s: 1 }.build(p, 1234)),
        ("FJLT", |p, k| MethodSpec::Fjlt { k }.build(p, 1234)),
        ("Dense(±1)", |p, k| {
            Box::new(GaussianProjection::rademacher(p, k, 1234))
        }),
    ];
    let mut table = Table::new(
        &format!("Figure 4 — projection benchmark, p = {p}"),
        &[
            "method", "k", "sparsity", "time/proj", "time sparse-path", "rel-err",
        ],
    );
    for &(name, build) in &methods {
        for &k in ks {
            let c = build(p, k);
            for &zf in SPARSITY_LEVELS {
                let xs = make_inputs(p, 4, zf, 7 + (zf * 100.0) as u64);
                let mut out = vec![0.0f32; k];
                // dense-input path
                let r = bench::bench_with_budget(
                    &format!("{name}/k={k}/z={zf}"),
                    Duration::from_millis(budget_ms),
                    || c.compress_into(&xs[0], &mut out),
                );
                // sparse-input path (paper: complexity scales with nnz)
                let (idx, vals) = sparse_view(&xs[0]);
                let rs = bench::bench_with_budget(
                    &format!("{name}/k={k}/z={zf}/sparse"),
                    Duration::from_millis(budget_ms),
                    || c.compress_sparse_into(&idx, &vals, &mut out),
                );
                let cs: Vec<Vec<f32>> = xs.iter().map(|x| c.compress(x)).collect();
                let err = relative_distance_error(&xs, &cs);
                table.row(vec![
                    name.to_string(),
                    k.to_string(),
                    format!("{:.0}%", zf * 100.0),
                    super::report::fmt_secs(r.median_secs()),
                    super::report::fmt_secs(rs.median_secs()),
                    format!("{err:.4}"),
                ]);
            }
        }
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_error_zero_for_identity() {
        let xs = vec![vec![1.0, 2.0], vec![3.0, -1.0], vec![0.0, 0.5]];
        assert!(relative_distance_error(&xs, &xs) < 1e-12);
    }

    #[test]
    fn sparse_inputs_have_requested_sparsity() {
        let xs = make_inputs(10_000, 2, 0.9, 1);
        for x in &xs {
            let nnz = x.iter().filter(|&&v| v != 0.0).count();
            assert!((500..1500).contains(&nnz), "nnz = {nnz}");
        }
    }

    #[test]
    fn tiny_run_produces_rows() {
        // Shrunk p not possible (constant), but small k + tiny budget works.
        let t = run(&[64], 5, None).unwrap();
        assert_eq!(t.rows.len(), 3 * SPARSITY_LEVELS.len());
    }
}
