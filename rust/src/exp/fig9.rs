//! Figure 9 — qualitative attribution: train the tiny LM on the themed
//! corpus, attribute a themed query prompt with FactGraSS + block-diagonal
//! FIM influence, and check that the top-influence documents share the
//! query's theme (the synthetic analogue of the paper's "To improve data
//! privacy" → journalist-jailing/privacy-policy document example).

use super::report::Table;
use super::table1::{collect_hooks, compress_hooks};
use crate::attrib::blockwise::{BlockLayout, BlockwiseEngine};
use crate::config::ExpConfig;
use crate::data::corpus::{ThemedCorpus, THEMES};
use crate::data::Sequences;
use crate::eval::retrain::{TaskData, Trainer};
use crate::runtime::Runtime;
use crate::sketch::{MaskKind, MethodSpec};
use anyhow::Result;

pub struct Fig9Outcome {
    pub table: Table,
    /// Fraction of top-10 influential docs sharing the query theme.
    pub top10_theme_hit: f64,
    pub query_theme: &'static str,
}

pub fn run(rt: &Runtime, cfg: &ExpConfig, kl: usize) -> Result<Fig9Outcome> {
    let model = "gpt2_tiny";
    let meta = rt.manifest.model(model)?.clone();
    let seq = meta.seq.unwrap();
    let train = ThemedCorpus::generate(cfg.n_train, seq, cfg.seed);
    let trainer = Trainer::new(rt, model)?;
    let all: Vec<usize> = (0..train.n).collect();

    eprintln!("[fig9] training base LM on {} themed docs", train.n);
    let init = trainer.init(4000)?;
    let params = trainer.train(
        init,
        &TaskData::Sequences(&train),
        &all,
        cfg.epochs,
        cfg.lr,
        cfg.seed ^ 0xF19,
    )?;

    // Query: a fresh privacy-themed prompt (theme 0).
    let query_theme = 0usize;
    let qtokens = ThemedCorpus::query(query_theme, seq, cfg.seed ^ 0x900D);
    let queries = Sequences {
        tokens: qtokens.clone(),
        seq,
        n: 1,
        tags: vec![query_theme as u32],
    };

    // FactGraSS compression of train + query hooks, constructed through
    // the declarative spec (one bank, shared by both sides).
    let hooks_train = collect_hooks(rt, model, &params, &train, &all)?;
    let hooks_q = collect_hooks(rt, model, &params, &queries, &[0])?;
    let k_side = (kl as f64).sqrt() as usize;
    let spec = MethodSpec::FactGrass {
        k: kl,
        k_in: 2 * k_side,
        k_out: 2 * k_side,
        mask: MaskKind::Random,
    };
    let bank = spec.build_bank(&meta.shapes(), cfg.seed ^ 0x400)?;
    let banks = bank.as_factored().expect("factorized spec builds a factored bank");
    let dims = bank.layer_dims();
    let (ctr, _) = compress_hooks(&hooks_train, banks);
    let (cq, _) = compress_hooks(&hooks_q, banks);

    let engine = BlockwiseEngine::new(BlockLayout::new(dims), 1e-3);
    let scores = engine.attribute(&ctr, train.n, &cq, 1)?;

    // Rank training docs by influence; the paper filters outliers by
    // gradient norm — here we simply rank and inspect the top 10.
    let mut order: Vec<usize> = (0..train.n).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

    let mut table = Table::new(
        &format!(
            "Figure 9 — top influential docs for a '{}' query (FactGraSS k_l={kl})",
            THEMES[query_theme]
        ),
        &["rank", "doc", "theme", "score", "same theme?"],
    );
    let mut hits = 0;
    for (rank, &i) in order.iter().take(10).enumerate() {
        let theme = train.tags[i] as usize;
        let same = theme == query_theme;
        if same {
            hits += 1;
        }
        let preview: String = train
            .sample(i)
            .iter()
            .take(32)
            .map(|&b| b as u8 as char)
            .collect();
        table.row(vec![
            (rank + 1).to_string(),
            format!("#{i} \"{preview}…\""),
            THEMES[theme].to_string(),
            format!("{:.4}", scores[i]),
            if same { "✓".into() } else { "✗".into() },
        ]);
    }
    Ok(Fig9Outcome {
        table,
        top10_theme_hit: hits as f64 / 10.0,
        query_theme: THEMES[query_theme],
    })
}
