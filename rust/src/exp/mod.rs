//! Experiment harnesses regenerating every table and figure in the paper's
//! evaluation (see DESIGN.md §3 for the index):
//!
//! * [`fig4`] — projection micro-benchmark (speed + relative error vs
//!   sparsity) at p = 131072.
//! * [`table1`] — LDS + compression wall-time: (a) MLP, (b) ResNet-lite,
//!   (c) music transformer (TRAK); (d) GPT2-tiny with layer-wise
//!   block-diagonal FIM and factorized compression.
//! * [`table2`] — billion-scale throughput: FactGraSS vs LoGra over the
//!   exact Llama-3.1-8B layer geometry.
//! * [`fig9`] — qualitative attribution on the themed corpus.

pub mod ablation;
pub mod fig4;
pub mod fig9;
pub mod report;
pub mod table1;
pub mod table2;
