//! Table 2 — billion-scale throughput: FactGraSS vs LoGra over the exact
//! Llama-3.1-8B linear-layer geometry (tokens/second).
//!
//! The paper measures two rates on one H200:
//!   * **compress** — projected gradients from layer inputs + pre-activation
//!     gradients (the per-layer factorized compress step);
//!   * **cache** — compress + persist the projected gradients.
//!
//! Weight values are irrelevant to compression cost, so activations and
//! pre-activation gradients are synthetic with the true shapes
//! (DESIGN.md §5). The claim to preserve is the *ratio*: FactGraSS ≥ 1.6×
//! LoGra on compress, ≈ 1.17× on cache.

use super::report::Table;
use crate::models::shapes::{llama8b_layers, LayerShape};
use crate::sketch::rng::Pcg;
use crate::sketch::{factgrass::FactGrass, logra::LoGra, FactorizedCompressor, MaskKind};
use crate::store::StoreWriter;
use anyhow::Result;
use std::time::Instant;

/// One benchmark workload: activations for a micro-batch of token blocks.
pub struct Workload {
    /// (x: T×d_in, dy: T×d_out) per distinct layer shape.
    pub acts: Vec<(Vec<f32>, Vec<f32>)>,
    pub t: usize,
}

pub fn make_workload(layers: &[LayerShape], t: usize, seed: u64) -> Workload {
    let mut rng = Pcg::new(seed);
    let acts = layers
        .iter()
        .map(|l| {
            let x: Vec<f32> = (0..t * l.d_in).map(|_| rng.next_gaussian()).collect();
            let dy: Vec<f32> = (0..t * l.d_out).map(|_| rng.next_gaussian()).collect();
            (x, dy)
        })
        .collect();
    Workload { acts, t }
}

/// Compressor banks for one method across the layer stack.
fn build_banks(
    layers: &[LayerShape],
    kl: usize,
    factgrass: bool,
    seed: u64,
) -> Vec<Box<dyn FactorizedCompressor>> {
    let k_side = (kl as f64).sqrt() as usize;
    layers
        .iter()
        .enumerate()
        .map(|(i, l)| -> Box<dyn FactorizedCompressor> {
            if factgrass {
                // paper default: SJLT_{k_l} ∘ RM_{2k_in ⊗ 2k_out}
                Box::new(FactGrass::new(
                    l.d_in,
                    l.d_out,
                    (2 * k_side).min(l.d_in),
                    (2 * k_side).min(l.d_out),
                    kl,
                    MaskKind::Random,
                    seed + i as u64,
                ))
            } else {
                Box::new(LoGra::new(l.d_in, l.d_out, k_side, k_side, seed + i as u64))
            }
        })
        .collect()
}

/// Run one method over `reps` sweeps of every layer instance; returns
/// (compress tokens/s, cache tokens/s).
pub fn measure(
    layers: &[LayerShape],
    wl: &Workload,
    kl: usize,
    factgrass: bool,
    reps: usize,
    blocks: usize,
    store_dir: &std::path::Path,
) -> Result<(f64, f64)> {
    // `blocks` instances of each layer shape are actually executed; the
    // full-model rate is extrapolated by blocks/count (per-block cost is
    // identical, so the FactGraSS:LoGra ratio is exact).
    let banks = build_banks(layers, kl, factgrass, 7);
    let total_k: usize = banks.iter().map(|b| b.output_dim()).sum::<usize>();
    let mut row = vec![0.0f32; total_k];

    // warmup sweep (page-in activations, settle the thread pool)
    {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            pos += bank.output_dim();
        }
    }

    // compress-only pass
    let mut tokens = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            // `blocks` instances of this layer shape process the block
            for _ in 0..blocks.min(layers[li].count) {
                bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            }
            pos += bank.output_dim();
        }
        tokens += wl.t as u64;
    }
    let frac = blocks.min(layers[0].count) as f64 / layers[0].count as f64;
    let compress_tps = tokens as f64 / t0.elapsed().as_secs_f64() * frac;

    // cache pass = compress + store write
    let mut writer = StoreWriter::create(
        store_dir,
        total_k,
        if factgrass { "factgrass" } else { "logra" },
        0,
        1024,
    )?;
    let mut tokens = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            for _ in 0..blocks.min(layers[li].count) {
                bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            }
            pos += bank.output_dim();
        }
        writer.push(&row)?;
        tokens += wl.t as u64;
    }
    let cache_tps = tokens as f64 / t0.elapsed().as_secs_f64() * frac;
    writer.finish()?;
    std::fs::remove_dir_all(store_dir).ok();
    Ok((compress_tps, cache_tps))
}

pub fn run(kls: &[usize], t: usize, reps: usize, out_json: Option<&str>) -> Result<Table> {
    run_with_blocks(kls, t, reps, 2, out_json)
}

pub fn run_with_blocks(
    kls: &[usize],
    t: usize,
    reps: usize,
    blocks: usize,
    out_json: Option<&str>,
) -> Result<Table> {
    let layers = llama8b_layers();
    let wl = make_workload(&layers, t, 99);
    let mut table = Table::new(
        &format!("Table 2 — Llama-3.1-8B geometry throughput (T = {t} tokens/block)"),
        &[
            "method",
            "k_l",
            "compress tok/s",
            "cache tok/s",
            "speedup vs LoGra",
        ],
    );
    let tmp = std::env::temp_dir().join(format!("grass_t2_{}", std::process::id()));
    for &kl in kls {
        let (lc, lcache) = measure(&layers, &wl, kl, false, reps, blocks, &tmp)?;
        let (fc, fcache) = measure(&layers, &wl, kl, true, reps, blocks, &tmp)?;
        table.row(vec![
            "LoGra".into(),
            kl.to_string(),
            format!("{lc:.0}"),
            format!("{lcache:.0}"),
            "1.00x".into(),
        ]);
        table.row(vec![
            "FactGraSS".into(),
            kl.to_string(),
            format!("{fc:.0}"),
            format!("{fcache:.0}"),
            format!("{:.2}x", fc / lc),
        ]);
        eprintln!("[table2] k_l={kl}: LoGra {lc:.0} tok/s, FactGraSS {fc:.0} tok/s ({:.2}x)", fc / lc);
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_measure_runs_and_factgrass_wins() {
        // Shrunken stack: one layer shape, small T — sanity + ordering.
        let layers = vec![LayerShape::new("l", 512, 512, 2)];
        let wl = make_workload(&layers, 16, 1);
        let tmp = std::env::temp_dir().join(format!("grass_t2_test_{}", std::process::id()));
        let (lc, lcache) = measure(&layers, &wl, 64, false, 3, 2, &tmp).unwrap();
        let (fc, fcache) = measure(&layers, &wl, 64, true, 3, 2, &tmp).unwrap();
        assert!(lc > 0.0 && fc > 0.0 && lcache > 0.0 && fcache > 0.0);
        // FactGraSS must beat LoGra on the compress step (the paper's claim).
        assert!(
            fc > lc,
            "FactGraSS ({fc:.0} tok/s) should beat LoGra ({lc:.0} tok/s)"
        );
    }

    #[test]
    fn workload_shapes() {
        let layers = llama8b_layers();
        let wl = make_workload(&layers, 4, 2);
        assert_eq!(wl.acts.len(), layers.len());
        assert_eq!(wl.acts[0].0.len(), 4 * 4096);
        assert_eq!(wl.acts[6].0.len(), 4 * 14336); // down_proj input
    }
}
