//! Table 2 — billion-scale throughput: FactGraSS vs LoGra over the exact
//! Llama-3.1-8B linear-layer geometry (tokens/second).
//!
//! The paper measures two rates on one H200:
//!   * **compress** — projected gradients from layer inputs + pre-activation
//!     gradients (the per-layer factorized compress step);
//!   * **cache** — compress + persist the projected gradients.
//!
//! Weight values are irrelevant to compression cost, so activations and
//! pre-activation gradients are synthetic with the true shapes
//! (DESIGN.md §5). The claim to preserve is the *ratio*: FactGraSS ≥ 1.6×
//! LoGra on compress, ≈ 1.17× on cache.

use super::report::Table;
use crate::models::shapes::{llama8b_layers, LayerShape, ModelShapes};
use crate::sketch::rng::Pcg;
use crate::sketch::{FactorizedCompressor, MaskKind, MethodSpec, Scratch, SparseRows};
use crate::store::StoreWriter;
use crate::util::bench::BenchRecord;
use anyhow::Result;
use std::time::{Duration, Instant};

/// One benchmark workload: activations for a micro-batch of token blocks.
pub struct Workload {
    /// (x: T×d_in, dy: T×d_out) per distinct layer shape.
    pub acts: Vec<(Vec<f32>, Vec<f32>)>,
    pub t: usize,
}

pub fn make_workload(layers: &[LayerShape], t: usize, seed: u64) -> Workload {
    let mut rng = Pcg::new(seed);
    let acts = layers
        .iter()
        .map(|l| {
            let x: Vec<f32> = (0..t * l.d_in).map(|_| rng.next_gaussian()).collect();
            let dy: Vec<f32> = (0..t * l.d_out).map(|_| rng.next_gaussian()).collect();
            (x, dy)
        })
        .collect();
    Workload { acts, t }
}

/// Compressor banks for one method across the layer stack, built through
/// the declarative spec (the same path the pipeline and CLI use).
fn build_banks(
    layers: &[LayerShape],
    kl: usize,
    factgrass: bool,
    seed: u64,
) -> Vec<Box<dyn FactorizedCompressor>> {
    let k_side = (kl as f64).sqrt() as usize;
    let spec = if factgrass {
        // paper default: SJLT_{k_l} ∘ RM_{2k_in ⊗ 2k_out}
        MethodSpec::FactGrass {
            k: kl,
            k_in: 2 * k_side,
            k_out: 2 * k_side,
            mask: MaskKind::Random,
        }
    } else {
        MethodSpec::LoGra {
            k_in: k_side,
            k_out: k_side,
        }
    };
    spec.build_bank(&ModelShapes::from_layer_shapes(layers), seed)
        .expect("table2 bank construction")
        .into_factored()
        .expect("factorized spec builds a factored bank")
}

/// Run one method over `reps` sweeps of every layer instance; returns
/// (compress tokens/s, cache tokens/s).
pub fn measure(
    layers: &[LayerShape],
    wl: &Workload,
    kl: usize,
    factgrass: bool,
    reps: usize,
    blocks: usize,
    store_dir: &std::path::Path,
) -> Result<(f64, f64)> {
    // `blocks` instances of each layer shape are actually executed; the
    // full-model rate is extrapolated by blocks/count (per-block cost is
    // identical, so the FactGraSS:LoGra ratio is exact).
    let banks = build_banks(layers, kl, factgrass, 7);
    let total_k: usize = banks.iter().map(|b| b.output_dim()).sum::<usize>();
    let mut row = vec![0.0f32; total_k];

    // warmup sweep (page-in activations, settle the thread pool)
    {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            pos += bank.output_dim();
        }
    }

    // compress-only pass
    let mut tokens = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            // `blocks` instances of this layer shape process the block
            for _ in 0..blocks.min(layers[li].count) {
                bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            }
            pos += bank.output_dim();
        }
        tokens += wl.t as u64;
    }
    let frac = blocks.min(layers[0].count) as f64 / layers[0].count as f64;
    let compress_tps = tokens as f64 / t0.elapsed().as_secs_f64() * frac;

    // cache pass = compress + store write
    let mut writer = StoreWriter::create(
        store_dir,
        total_k,
        if factgrass { "factgrass" } else { "logra" },
        0,
        1024,
    )?;
    let mut tokens = 0u64;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut pos = 0;
        for (li, bank) in banks.iter().enumerate() {
            let (x, dy) = &wl.acts[li];
            for _ in 0..blocks.min(layers[li].count) {
                bank.compress_into(wl.t, x, dy, &mut row[pos..pos + bank.output_dim()]);
            }
            pos += bank.output_dim();
        }
        writer.push(&row)?;
        tokens += wl.t as u64;
    }
    let cache_tps = tokens as f64 / t0.elapsed().as_secs_f64() * frac;
    writer.finish()?;
    std::fs::remove_dir_all(store_dir).ok();
    Ok((compress_tps, cache_tps))
}

/// Batched variant of [`measure`]: a micro-batch of `batch` samples flows
/// through the batch-first kernels (`compress_batch_with`) with one
/// reusable [`Scratch`] — the pipeline's compress-stage execution model.
/// Layers are measured one at a time so only a single layer's replicated
/// activations are resident. Returns (compress tokens/s, cache tokens/s).
#[allow(clippy::too_many_arguments)]
pub fn measure_batched(
    layers: &[LayerShape],
    wl: &Workload,
    kl: usize,
    factgrass: bool,
    reps: usize,
    blocks: usize,
    batch: usize,
    store_dir: &std::path::Path,
) -> Result<(f64, f64)> {
    let banks = build_banks(layers, kl, factgrass, 7);
    let total_k: usize = banks.iter().map(|b| b.output_dim()).sum::<usize>();
    let mut rows = vec![0.0f32; batch * total_k];
    let mut scratch = Scratch::new();
    let t = wl.t;

    let mut compress_elapsed = Duration::ZERO;
    let mut off = 0usize;
    for (li, bank) in banks.iter().enumerate() {
        // Replicate this layer's activation block for each batch sample.
        let (x, dy) = &wl.acts[li];
        let mut xb = scratch.take_f32(batch * x.len());
        let mut db = scratch.take_f32(batch * dy.len());
        for i in 0..batch {
            xb[i * x.len()..(i + 1) * x.len()].copy_from_slice(x);
            db[i * dy.len()..(i + 1) * dy.len()].copy_from_slice(dy);
        }
        // warmup (page in, settle the pool)
        bank.compress_batch_with(batch, t, &xb, &db, &mut rows, total_k, off, &mut scratch);
        let t0 = Instant::now();
        for _ in 0..reps {
            for _ in 0..blocks.min(layers[li].count) {
                bank.compress_batch_with(batch, t, &xb, &db, &mut rows, total_k, off, &mut scratch);
            }
        }
        compress_elapsed += t0.elapsed();
        scratch.put_f32(xb);
        scratch.put_f32(db);
        off += bank.output_dim();
    }
    let tokens = (reps * batch * t) as u64;
    let frac = blocks.min(layers[0].count) as f64 / layers[0].count as f64;
    let compress_tps = tokens as f64 / compress_elapsed.as_secs_f64().max(1e-12) * frac;

    // cache = compress + persist: add the write cost of the same rows.
    let mut writer = StoreWriter::create(
        store_dir,
        total_k,
        if factgrass { "factgrass-batch" } else { "logra-batch" },
        0,
        1024,
    )?;
    let t0 = Instant::now();
    for _ in 0..reps {
        writer.push_batch(&rows)?;
    }
    let write_elapsed = t0.elapsed();
    writer.finish()?;
    std::fs::remove_dir_all(store_dir).ok();
    let cache_tps = tokens as f64
        / (compress_elapsed + write_elapsed).as_secs_f64().max(1e-12)
        * frac;
    Ok((compress_tps, cache_tps))
}

/// One sparse-vs-dense kernel measurement at a fixed activation `density`:
/// identical banks, shapes, and `(p, k, s)` on both sides — only the
/// execution path differs (dense batch kernels vs the CSR kernels fed by
/// [`SparseRows::from_dense_threshold`]). Returns
/// `(dense tok/s, sparse tok/s, measured density, mean nnz per row)`.
#[allow(clippy::too_many_arguments)]
pub fn measure_density(
    layers: &[LayerShape],
    kl: usize,
    factgrass: bool,
    t: usize,
    reps: usize,
    blocks: usize,
    batch: usize,
    density: f64,
    seed: u64,
) -> Result<(f64, f64, f64, f64)> {
    let banks = build_banks(layers, kl, factgrass, 7);
    let total_k: usize = banks.iter().map(|b| b.output_dim()).sum();
    let mut rows_dense = vec![0.0f32; batch * total_k];
    let mut rows_sparse = vec![0.0f32; batch * total_k];
    let mut scratch = Scratch::new();
    let mut rng = Pcg::new(seed);

    let mut dense_elapsed = Duration::ZERO;
    let mut sparse_elapsed = Duration::ZERO;
    let (mut nnz_total, mut elems_total, mut rows_count) = (0usize, 0usize, 0usize);
    let mut off = 0usize;
    for (li, bank) in banks.iter().enumerate() {
        let (d_in, d_out) = (bank.d_in(), bank.d_out());
        let nt = batch * t;
        let mut gen = |len: usize| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    if rng.next_f64() < density {
                        rng.next_gaussian()
                    } else {
                        0.0
                    }
                })
                .collect()
        };
        let x = gen(nt * d_in);
        let dy = gen(nt * d_out);
        let xs = SparseRows::from_dense_threshold(&x, nt, d_in, 0.0);
        let dys = SparseRows::from_dense_threshold(&dy, nt, d_out, 0.0);
        nnz_total += xs.nnz_total() + dys.nnz_total();
        elems_total += x.len() + dy.len();
        rows_count += 2 * nt;
        let iters = blocks.min(layers[li].count);
        // warmup both paths (page in, settle the pool)
        bank.compress_batch_with(batch, t, &x, &dy, &mut rows_dense, total_k, off, &mut scratch);
        bank.compress_sparse_batch_with(
            batch,
            t,
            &xs,
            &dys,
            &mut rows_sparse,
            total_k,
            off,
            &mut scratch,
        );
        let t0 = Instant::now();
        for _ in 0..reps {
            for _ in 0..iters {
                bank.compress_batch_with(
                    batch,
                    t,
                    &x,
                    &dy,
                    &mut rows_dense,
                    total_k,
                    off,
                    &mut scratch,
                );
            }
        }
        dense_elapsed += t0.elapsed();
        let t0 = Instant::now();
        for _ in 0..reps {
            for _ in 0..iters {
                bank.compress_sparse_batch_with(
                    batch,
                    t,
                    &xs,
                    &dys,
                    &mut rows_sparse,
                    total_k,
                    off,
                    &mut scratch,
                );
            }
        }
        sparse_elapsed += t0.elapsed();
        off += bank.output_dim();
    }
    let tokens = (reps * batch * t) as f64;
    let frac = blocks.min(layers[0].count) as f64 / layers[0].count as f64;
    let dense_tps = tokens / dense_elapsed.as_secs_f64().max(1e-12) * frac;
    let sparse_tps = tokens / sparse_elapsed.as_secs_f64().max(1e-12) * frac;
    let measured = nnz_total as f64 / (elems_total as f64).max(1.0);
    let mean_nnz = nnz_total as f64 / (rows_count as f64).max(1.0);
    Ok((dense_tps, sparse_tps, measured, mean_nnz))
}

/// Density sweep at one `k_l`: dense batch kernels vs the CSR kernels for
/// both methods at each density, on the Llama-3.1-8B geometry. The bench
/// target appends these records to `BENCH_table2_throughput.json`; the CI
/// gate asserts the sparse path wins (≥3×) at 1% density for LoGra — the
/// dense-projection baseline whose cost is `O(d·k)` per row against the
/// CSR path's `O(nnz·k)`.
pub fn run_density(
    kl: usize,
    t: usize,
    reps: usize,
    blocks: usize,
    batch: usize,
    densities: &[f64],
) -> Result<(Table, Vec<BenchRecord>)> {
    let layers = llama8b_layers();
    let elems_per_token: usize = layers.iter().map(|l| l.d_in + l.d_out).sum();
    let mut table = Table::new(
        &format!("Table 2b — sparse vs dense kernels by input density (k_l = {kl}, T = {t})"),
        &[
            "method",
            "density",
            "dense tok/s",
            "sparse tok/s",
            "sparse speedup",
        ],
    );
    let mut records = Vec::new();
    for &density in densities {
        for (name, factgrass) in [("logra", false), ("factgrass", true)] {
            let (dense_tps, sparse_tps, measured, mean_nnz) =
                measure_density(&layers, kl, factgrass, t, reps, blocks, batch, density, 0xD5)?;
            let speedup = sparse_tps / dense_tps.max(1e-12);
            table.row(vec![
                name.into(),
                format!("{density}"),
                format!("{dense_tps:.0}"),
                format!("{sparse_tps:.0}"),
                format!("{speedup:.2}x"),
            ]);
            records.push(
                BenchRecord {
                    method: format!("{name}:kl={kl}:density={density}:sparse"),
                    n: batch,
                    p: t * elems_per_token,
                    k: kl,
                    samples_per_sec: sparse_tps / t as f64,
                    ns_per_elem: 1e9 / (sparse_tps * elems_per_token as f64).max(1e-12),
                    density: Some(measured),
                    mean_nnz: Some(mean_nnz),
                    precond_fit_ms: None,
                    precond_apply_ms: None,
                    resume_skipped_rows: None,
                    retries_attempted: None,
                    qps: None,
                    p50_ms: None,
                    p95_ms: None,
                    p99_ms: None,
                    cache_hit_rate: None,
                    availability: None,
                    sheds: None,
                    dtype: None,
                    bytes_per_row: None,
                    extra: vec![
                        ("tokens_per_sec".to_string(), sparse_tps),
                        ("dense_tokens_per_sec".to_string(), dense_tps),
                        ("sparse_speedup".to_string(), speedup),
                    ],
                },
            );
            eprintln!(
                "[table2-density] {name} k_l={kl} density={density}: \
                 dense {dense_tps:.0} tok/s, sparse {sparse_tps:.0} tok/s ({speedup:.2}x)"
            );
        }
    }
    Ok((table, records))
}

pub fn run(kls: &[usize], t: usize, reps: usize, out_json: Option<&str>) -> Result<Table> {
    run_with_blocks(kls, t, reps, 2, out_json)
}

/// The paper's Table 2 exactly as before: per-sample measurement only, two
/// rows per `k_l` (the CLI path — the batched sweep is opt-in via
/// [`run_bench`], which the bench target uses).
pub fn run_with_blocks(
    kls: &[usize],
    t: usize,
    reps: usize,
    blocks: usize,
    out_json: Option<&str>,
) -> Result<Table> {
    let layers = llama8b_layers();
    let wl = make_workload(&layers, t, 99);
    let mut table = Table::new(
        &format!("Table 2 — Llama-3.1-8B geometry throughput (T = {t} tokens/block)"),
        &[
            "method",
            "k_l",
            "compress tok/s",
            "cache tok/s",
            "speedup vs LoGra",
        ],
    );
    let tmp = std::env::temp_dir().join(format!("grass_t2_{}", std::process::id()));
    for &kl in kls {
        let (lc, lcache) = measure(&layers, &wl, kl, false, reps, blocks, &tmp)?;
        let (fc, fcache) = measure(&layers, &wl, kl, true, reps, blocks, &tmp)?;
        table.row(vec![
            "LoGra".into(),
            kl.to_string(),
            format!("{lc:.0}"),
            format!("{lcache:.0}"),
            "1.00x".into(),
        ]);
        table.row(vec![
            "FactGraSS".into(),
            kl.to_string(),
            format!("{fc:.0}"),
            format!("{fcache:.0}"),
            format!("{:.2}x", fc / lc),
        ]);
        eprintln!(
            "[table2] k_l={kl}: LoGra {lc:.0} tok/s, FactGraSS {fc:.0} tok/s ({:.2}x)",
            fc / lc
        );
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok(table)
}

/// Full Table 2 sweep: per `k_l`, both methods on both execution models
/// (per-sample `compress_into` loop vs the batch-first kernels). Returns
/// the printable table plus machine-readable [`BenchRecord`]s, so the bench
/// target can persist `BENCH_table2_throughput.json`. The per-sample rows
/// are the baseline the ≥2× batch-speedup acceptance gate compares against.
pub fn run_bench(
    kls: &[usize],
    t: usize,
    reps: usize,
    blocks: usize,
    batch: usize,
    out_json: Option<&str>,
) -> Result<(Table, Vec<BenchRecord>)> {
    let layers = llama8b_layers();
    let wl = make_workload(&layers, t, 99);
    let mut table = Table::new(
        &format!("Table 2 — Llama-3.1-8B geometry throughput (T = {t} tokens/block)"),
        &[
            "method",
            "k_l",
            "compress tok/s",
            "cache tok/s",
            "speedup vs LoGra",
            "batch speedup",
        ],
    );
    let elems_per_token: usize = layers.iter().map(|l| l.d_in + l.d_out).sum();
    let mut records = Vec::new();
    let record = |method: String, kl: usize, n: usize, tps: f64, cache: f64| -> BenchRecord {
        BenchRecord {
            method,
            n,
            p: t * elems_per_token,
            k: kl,
            samples_per_sec: tps / t as f64,
            ns_per_elem: 1e9 / (tps * elems_per_token as f64).max(1e-12),
            // The Gaussian workload is fully dense.
            density: Some(1.0),
            mean_nnz: Some((t * elems_per_token) as f64),
            precond_fit_ms: None,
            precond_apply_ms: None,
            resume_skipped_rows: None,
            retries_attempted: None,
            qps: None,
            p50_ms: None,
            p95_ms: None,
            p99_ms: None,
            cache_hit_rate: None,
            availability: None,
            sheds: None,
            dtype: None,
            bytes_per_row: None,
            extra: vec![
                ("tokens_per_sec".to_string(), tps),
                ("cache_tokens_per_sec".to_string(), cache),
            ],
        }
    };
    let tmp = std::env::temp_dir().join(format!("grass_t2_{}", std::process::id()));
    for &kl in kls {
        let (lc, lcache) = measure(&layers, &wl, kl, false, reps, blocks, &tmp)?;
        let (fc, fcache) = measure(&layers, &wl, kl, true, reps, blocks, &tmp)?;
        let (lcb, lcacheb) = measure_batched(&layers, &wl, kl, false, reps, blocks, batch, &tmp)?;
        let (fcb, fcacheb) = measure_batched(&layers, &wl, kl, true, reps, blocks, batch, &tmp)?;
        table.row(vec![
            "LoGra".into(),
            kl.to_string(),
            format!("{lc:.0}"),
            format!("{lcache:.0}"),
            "1.00x".into(),
            "-".into(),
        ]);
        table.row(vec![
            "FactGraSS".into(),
            kl.to_string(),
            format!("{fc:.0}"),
            format!("{fcache:.0}"),
            format!("{:.2}x", fc / lc),
            "-".into(),
        ]);
        table.row(vec![
            "LoGra (batch)".into(),
            kl.to_string(),
            format!("{lcb:.0}"),
            format!("{lcacheb:.0}"),
            "1.00x".into(),
            format!("{:.2}x", lcb / lc),
        ]);
        table.row(vec![
            "FactGraSS (batch)".into(),
            kl.to_string(),
            format!("{fcb:.0}"),
            format!("{fcacheb:.0}"),
            format!("{:.2}x", fcb / lcb),
            format!("{:.2}x", fcb / fc),
        ]);
        records.push(record(format!("logra:kl={kl}:per_sample"), kl, 1, lc, lcache));
        records.push(record(format!("factgrass:kl={kl}:per_sample"), kl, 1, fc, fcache));
        records.push(
            record(format!("logra:kl={kl}:batch"), kl, batch, lcb, lcacheb)
                .with("speedup_vs_per_sample", lcb / lc),
        );
        records.push(
            record(format!("factgrass:kl={kl}:batch"), kl, batch, fcb, fcacheb)
                .with("speedup_vs_per_sample", fcb / fc),
        );
        eprintln!(
            "[table2] k_l={kl}: LoGra {lc:.0} tok/s (batch {lcb:.0}), \
             FactGraSS {fc:.0} tok/s (batch {fcb:.0}, {:.2}x vs LoGra batch)",
            fcb / lcb
        );
    }
    if let Some(path) = out_json {
        table.save(path)?;
    }
    Ok((table, records))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_measure_runs_and_factgrass_wins() {
        // Shrunken stack: one layer shape, small T — sanity + ordering.
        let layers = vec![LayerShape::new("l", 512, 512, 2)];
        let wl = make_workload(&layers, 16, 1);
        let tmp = std::env::temp_dir().join(format!("grass_t2_test_{}", std::process::id()));
        let (lc, lcache) = measure(&layers, &wl, 64, false, 3, 2, &tmp).unwrap();
        let (fc, fcache) = measure(&layers, &wl, 64, true, 3, 2, &tmp).unwrap();
        assert!(lc > 0.0 && fc > 0.0 && lcache > 0.0 && fcache > 0.0);
        // FactGraSS must beat LoGra on the compress step (the paper's claim).
        assert!(
            fc > lc,
            "FactGraSS ({fc:.0} tok/s) should beat LoGra ({lc:.0} tok/s)"
        );
    }

    #[test]
    fn batched_measure_runs_and_is_positive() {
        let layers = vec![LayerShape::new("l", 256, 256, 2)];
        let wl = make_workload(&layers, 8, 2);
        let tmp = std::env::temp_dir().join(format!("grass_t2_btest_{}", std::process::id()));
        let (c, cache) = measure_batched(&layers, &wl, 16, true, 2, 2, 3, &tmp).unwrap();
        assert!(c > 0.0 && cache > 0.0);
        let (cl, cachel) = measure_batched(&layers, &wl, 16, false, 2, 2, 3, &tmp).unwrap();
        assert!(cl > 0.0 && cachel > 0.0);
    }

    #[test]
    fn measure_density_reports_sane_rates_and_density() {
        // Correctness of the harness only: both paths produce positive
        // rates and the measured density/nnz track the request. The
        // sparse-beats-dense *ordering* is asserted by the release-mode
        // table2_throughput CI gate (≥3× for LoGra at 1% density), not
        // here — a debug-build wall-clock race under a loaded test runner
        // would make it a tier-1 flake.
        let layers = vec![LayerShape::new("l", 1024, 1024, 2)];
        let (dense, sparse, measured, mean_nnz) =
            measure_density(&layers, 16, false, 8, 2, 2, 2, 0.01, 1).unwrap();
        assert!(dense > 0.0 && sparse > 0.0);
        assert!(measured < 0.05, "measured density {measured}");
        assert!((1.0..=1024.0).contains(&mean_nnz), "mean_nnz {mean_nnz}");
        let (fd, fs, _, _) = measure_density(&layers, 16, true, 8, 2, 2, 2, 0.01, 2).unwrap();
        assert!(fd > 0.0 && fs > 0.0);
    }

    #[test]
    fn workload_shapes() {
        let layers = llama8b_layers();
        let wl = make_workload(&layers, 4, 2);
        assert_eq!(wl.acts.len(), layers.len());
        assert_eq!(wl.acts[0].0.len(), 4 * 4096);
        assert_eq!(wl.acts[6].0.len(), 4 * 14336); // down_proj input
    }
}
