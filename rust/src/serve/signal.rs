//! Std-only SIGTERM/SIGINT capture for the serving daemon.
//!
//! The handler is the minimal async-signal-safe kind: one atomic store
//! into a process-global flag. The accept loop (already a non-blocking
//! poll so a signal flag is enough to wake it) observes the flag on its
//! next tick and enters the same drain sequence a protocol `shutdown`
//! request uses. No `libc` crate — the C `signal(2)` entry point is
//! declared directly; on non-Unix targets installation is a no-op and the
//! protocol `shutdown` request remains the only trigger.
//!
//! Handlers are installed by [`install`] from the CLI path
//! ([`crate::serve::run`]) only — library embedders and tests that
//! [`crate::serve::spawn`] a daemon in-process never have their process
//! signal disposition hijacked.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

/// Last shutdown signal received (0 = none).
static PENDING: AtomicUsize = AtomicUsize::new(0);

/// Whether this process opted into signal-driven drains ([`install`]).
/// [`pending`] reports nothing until armed, so in-process daemons
/// (tests, embedders) never react to flags they did not ask for.
static WATCHED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(sig: i32) {
    // Async-signal-safe: a single atomic store.
    PENDING.store(sig as usize, Ordering::SeqCst);
}

/// Install SIGTERM/SIGINT handlers that flag a draining shutdown.
/// Idempotent; no-op on non-Unix targets.
#[cfg(unix)]
pub fn install() {
    WATCHED.store(true, Ordering::SeqCst);
    extern "C" {
        // `sighandler_t signal(int signum, sighandler_t handler)` — both
        // handler values are pointer-sized, so `usize` matches the ABI on
        // every supported Unix target.
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let handler = on_signal as extern "C" fn(i32) as usize;
    unsafe {
        signal(SIGTERM, handler);
        signal(SIGINT, handler);
    }
}

#[cfg(not(unix))]
pub fn install() {
    WATCHED.store(true, Ordering::SeqCst);
}

/// The signal name pending shutdown, if one was received. Always `None`
/// until [`install`] armed this process.
pub fn pending() -> Option<&'static str> {
    if !WATCHED.load(Ordering::SeqCst) {
        return None;
    }
    match PENDING.load(Ordering::SeqCst) as i32 {
        SIGINT => Some("SIGINT"),
        SIGTERM => Some("SIGTERM"),
        _ => None,
    }
}

/// Clear both flags (tests that raise signals in-process).
#[cfg(any(test, feature = "fault-injection"))]
pub fn reset() {
    PENDING.store(0, Ordering::SeqCst);
    WATCHED.store(false, Ordering::SeqCst);
}

/// Arm [`pending`] without touching the process signal disposition
/// (tests that simulate signal delivery in-process).
#[cfg(any(test, feature = "fault-injection"))]
pub fn arm_for_tests() {
    WATCHED.store(true, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pending_reports_the_stored_signal_only_when_armed() {
        reset();
        assert_eq!(pending(), None);
        on_signal(SIGTERM);
        assert_eq!(pending(), None, "unarmed process reports nothing");
        arm_for_tests();
        assert_eq!(pending(), Some("SIGTERM"));
        on_signal(SIGINT);
        assert_eq!(pending(), Some("SIGINT"));
        reset();
        assert_eq!(pending(), None);
    }
}
