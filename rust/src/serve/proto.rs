//! Versioned newline-delimited-JSON wire protocol for the serving daemon.
//!
//! Every message is one JSON object on one line, carrying `"v": 1` and a
//! `"type"` tag. Requests flow client → server, responses server → client;
//! both sides use [`crate::util::json::Json`] (no external deps). Unknown
//! versions and malformed frames are rejected with a typed
//! [`ErrorKind::BadRequest`] reply rather than a dropped connection.

use crate::util::json::Json;
use crate::Result;
use anyhow::{anyhow, bail, ensure};
use std::io::{BufRead, Write};

/// Protocol version spoken (and required) by this build.
pub const PROTO_VERSION: u64 = 1;

/// Largest accepted NDJSON frame (64 MiB). Far above any legitimate
/// request, far below what one malicious unterminated line would need to
/// OOM the daemon.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Typed failure classes a server reply can carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Admission control shed the request: the bounded queue was full.
    Overloaded,
    /// The request waited past its latency budget and was dropped unscored.
    DeadlineExceeded,
    /// The request was malformed, mis-versioned, or named an unknown scorer.
    BadRequest,
    /// The scoring path itself failed (store fatally unreadable, etc.).
    Internal,
}

impl ErrorKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::DeadlineExceeded => "deadline_exceeded",
            ErrorKind::BadRequest => "bad_request",
            ErrorKind::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "overloaded" => ErrorKind::Overloaded,
            "deadline_exceeded" => ErrorKind::DeadlineExceeded,
            "bad_request" => ErrorKind::BadRequest,
            "internal" => ErrorKind::Internal,
            other => bail!("unknown error kind {other:?}"),
        })
    }

    /// Whether this kind is an admission-control shed (client exit code 4)
    /// rather than a hard failure.
    pub fn is_shed(&self) -> bool {
        matches!(self, ErrorKind::Overloaded | ErrorKind::DeadlineExceeded)
    }
}

/// How a score request supplies its query gradients.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryPayload {
    /// Server-side synthetic queries (deterministic from the store seed) —
    /// the zero-bandwidth option for tests and smoke checks.
    Synth { m: usize },
    /// Raw per-query gradients (`m × input_dim`, row-major); the server
    /// compresses them through its resident bank. Flat methods only.
    Raw { m: usize, rows: Vec<f32> },
    /// Pre-compressed query sketches (`m × k`, row-major), used verbatim.
    Compressed { m: usize, rows: Vec<f32> },
}

impl QueryPayload {
    pub fn m(&self) -> usize {
        match self {
            QueryPayload::Synth { m }
            | QueryPayload::Raw { m, .. }
            | QueryPayload::Compressed { m, .. } => *m,
        }
    }

    fn to_json(&self) -> Json {
        match self {
            QueryPayload::Synth { m } => Json::obj(vec![
                ("kind", Json::Str("synth".into())),
                ("m", Json::Num(*m as f64)),
            ]),
            QueryPayload::Raw { m, rows } => Json::obj(vec![
                ("kind", Json::Str("raw".into())),
                ("m", Json::Num(*m as f64)),
                ("rows", Json::arr_f32(rows)),
            ]),
            QueryPayload::Compressed { m, rows } => Json::obj(vec![
                ("kind", Json::Str("compressed".into())),
                ("m", Json::Num(*m as f64)),
                ("rows", Json::arr_f32(rows)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or_default().to_string();
        let m = v.req("m")?.as_usize().unwrap_or(0);
        ensure!(m > 0, "query payload needs m >= 1");
        let rows = |v: &Json| -> Result<Vec<f32>> {
            let arr = v
                .req("rows")?
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
                .unwrap_or_default();
            Ok(arr)
        };
        Ok(match kind.as_str() {
            "synth" => QueryPayload::Synth { m },
            "raw" => QueryPayload::Raw { m, rows: rows(v)? },
            "compressed" => QueryPayload::Compressed { m, rows: rows(v)? },
            other => bail!("unknown query payload kind {other:?}"),
        })
    }
}

/// A scoring request: which scorer, how many neighbours, what queries.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreRequest {
    pub id: u64,
    pub scorer: String,
    /// Top-k training rows returned per query.
    pub top_k: usize,
    /// Include the full `m × n` score matrix in the reply (large!).
    pub include_scores: bool,
    /// Include per-query self-influence values.
    pub self_influence: bool,
    /// Per-request latency budget override (ms); `Some(0)` expires
    /// immediately, `None` uses the server default.
    pub deadline_ms: Option<u64>,
    pub queries: QueryPayload,
}

/// Client → server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Score(ScoreRequest),
    /// Ask for the daemon's metrics / hot-state snapshot.
    Stats { id: u64 },
    /// Liveness probe.
    Ping { id: u64 },
    /// Graceful shutdown: the daemon stops accepting, drains, and exits.
    Shutdown { id: u64 },
    /// Hot store reload: rebuild engines against the (possibly different)
    /// store directory and swap epochs without dropping in-flight requests.
    Reload { id: u64, store: Option<String> },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Score(r) => r.id,
            Request::Stats { id }
            | Request::Ping { id }
            | Request::Shutdown { id }
            | Request::Reload { id, .. } => *id,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("v", Json::Num(PROTO_VERSION as f64))];
        match self {
            Request::Score(r) => {
                pairs.push(("type", Json::Str("score".into())));
                pairs.push(("id", Json::Num(r.id as f64)));
                pairs.push(("scorer", Json::Str(r.scorer.clone())));
                pairs.push(("top_k", Json::Num(r.top_k as f64)));
                pairs.push(("include_scores", Json::Bool(r.include_scores)));
                pairs.push(("self_influence", Json::Bool(r.self_influence)));
                if let Some(d) = r.deadline_ms {
                    pairs.push(("deadline_ms", Json::Num(d as f64)));
                }
                pairs.push(("queries", r.queries.to_json()));
            }
            Request::Stats { id } => {
                pairs.push(("type", Json::Str("stats".into())));
                pairs.push(("id", Json::Num(*id as f64)));
            }
            Request::Ping { id } => {
                pairs.push(("type", Json::Str("ping".into())));
                pairs.push(("id", Json::Num(*id as f64)));
            }
            Request::Shutdown { id } => {
                pairs.push(("type", Json::Str("shutdown".into())));
                pairs.push(("id", Json::Num(*id as f64)));
            }
            Request::Reload { id, store } => {
                pairs.push(("type", Json::Str("reload".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                if let Some(store) = store {
                    pairs.push(("store", Json::Str(store.clone())));
                }
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        check_version(v)?;
        let id = v.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
        let ty = v.req("type")?.as_str().unwrap_or_default().to_string();
        Ok(match ty.as_str() {
            "score" => Request::Score(ScoreRequest {
                id,
                scorer: v.req("scorer")?.as_str().unwrap_or_default().to_string(),
                top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(5),
                include_scores: v
                    .get("include_scores")
                    .and_then(|x| x.as_bool())
                    .unwrap_or(false),
                self_influence: v
                    .get("self_influence")
                    .and_then(|x| x.as_bool())
                    .unwrap_or(false),
                deadline_ms: v.get("deadline_ms").and_then(|x| x.as_u64()),
                queries: QueryPayload::from_json(v.req("queries")?)?,
            }),
            "stats" => Request::Stats { id },
            "ping" => Request::Ping { id },
            "shutdown" => Request::Shutdown { id },
            "reload" => Request::Reload {
                id,
                store: v.get("store").and_then(|x| x.as_str()).map(String::from),
            },
            other => bail!("unknown request type {other:?}"),
        })
    }

    /// One-line wire frame (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }
}

/// Per-reply coverage: how much of the store actually contributed.
#[derive(Debug, Clone, PartialEq)]
pub struct CoverageInfo {
    pub rows_total: usize,
    pub rows_scored: usize,
    pub quarantined: Vec<usize>,
    pub retries_attempted: u64,
}

impl CoverageInfo {
    pub fn is_degraded(&self) -> bool {
        self.rows_scored < self.rows_total || !self.quarantined.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rows_total", Json::Num(self.rows_total as f64)),
            ("rows_scored", Json::Num(self.rows_scored as f64)),
            ("quarantined", Json::arr_usize(&self.quarantined)),
            ("retries_attempted", Json::Num(self.retries_attempted as f64)),
            ("degraded", Json::Bool(self.is_degraded())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            rows_total: v.req("rows_total")?.as_usize().unwrap_or(0),
            rows_scored: v.req("rows_scored")?.as_usize().unwrap_or(0),
            quarantined: v
                .get("quarantined")
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            retries_attempted: v
                .get("retries_attempted")
                .and_then(|x| x.as_u64())
                .unwrap_or(0),
        })
    }
}

/// A successful scoring reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreResponse {
    pub id: u64,
    pub scorer: String,
    pub m: usize,
    pub n: usize,
    /// Per-query `(train_row, score)` pairs, best first.
    pub top: Vec<Vec<(usize, f32)>>,
    /// Full `m × n` score matrix, row-major, when requested.
    pub scores: Option<Vec<f32>>,
    /// Per-query self-influence, when requested.
    pub self_influence: Option<Vec<f32>>,
    /// Synthetic query class labels, when the server generated the queries.
    pub classes: Option<Vec<usize>>,
    pub coverage: CoverageInfo,
    pub elapsed_ms: f64,
    /// Hot-state epoch that scored this reply (bumps on every reload; 0
    /// when the peer predates epochs).
    pub epoch: u64,
}

/// Server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Scores(Box<ScoreResponse>),
    Stats { id: u64, stats: Json },
    Pong { id: u64 },
    ShuttingDown { id: u64 },
    /// A hot reload completed: the daemon now serves `store` at `epoch`.
    Reloaded { id: u64, epoch: u64, store: String },
    Error { id: u64, kind: ErrorKind, message: String },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Scores(r) => r.id,
            Response::Stats { id, .. }
            | Response::Pong { id }
            | Response::ShuttingDown { id }
            | Response::Reloaded { id, .. }
            | Response::Error { id, .. } => *id,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("v", Json::Num(PROTO_VERSION as f64))];
        match self {
            Response::Scores(r) => {
                pairs.push(("type", Json::Str("scores".into())));
                pairs.push(("id", Json::Num(r.id as f64)));
                pairs.push(("scorer", Json::Str(r.scorer.clone())));
                pairs.push(("m", Json::Num(r.m as f64)));
                pairs.push(("n", Json::Num(r.n as f64)));
                let top = Json::Arr(
                    r.top
                        .iter()
                        .map(|q| {
                            Json::Arr(
                                q.iter()
                                    .map(|(i, s)| {
                                        Json::obj(vec![
                                            ("index", Json::Num(*i as f64)),
                                            ("score", Json::Num(*s as f64)),
                                        ])
                                    })
                                    .collect(),
                            )
                        })
                        .collect(),
                );
                pairs.push(("top", top));
                if let Some(scores) = &r.scores {
                    let rows = Json::Arr(
                        scores
                            .chunks(r.n.max(1))
                            .map(Json::arr_f32)
                            .collect(),
                    );
                    pairs.push(("scores", rows));
                }
                if let Some(si) = &r.self_influence {
                    pairs.push(("self_influence", Json::arr_f32(si)));
                }
                if let Some(classes) = &r.classes {
                    pairs.push(("classes", Json::arr_usize(classes)));
                }
                pairs.push(("coverage", r.coverage.to_json()));
                pairs.push(("elapsed_ms", Json::Num(r.elapsed_ms)));
                pairs.push(("epoch", Json::Num(r.epoch as f64)));
            }
            Response::Stats { id, stats } => {
                pairs.push(("type", Json::Str("stats".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("stats", stats.clone()));
            }
            Response::Pong { id } => {
                pairs.push(("type", Json::Str("pong".into())));
                pairs.push(("id", Json::Num(*id as f64)));
            }
            Response::ShuttingDown { id } => {
                pairs.push(("type", Json::Str("shutting_down".into())));
                pairs.push(("id", Json::Num(*id as f64)));
            }
            Response::Reloaded { id, epoch, store } => {
                pairs.push(("type", Json::Str("reloaded".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("epoch", Json::Num(*epoch as f64)));
                pairs.push(("store", Json::Str(store.clone())));
            }
            Response::Error { id, kind, message } => {
                pairs.push(("type", Json::Str("error".into())));
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("kind", Json::Str(kind.as_str().into())));
                pairs.push(("message", Json::Str(message.clone())));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        check_version(v)?;
        let id = v.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
        let ty = v.req("type")?.as_str().unwrap_or_default().to_string();
        Ok(match ty.as_str() {
            "scores" => {
                let n = v.req("n")?.as_usize().unwrap_or(0);
                let top = v
                    .req("top")?
                    .as_arr()
                    .map(|qs| {
                        qs.iter()
                            .map(|q| {
                                q.as_arr()
                                    .map(|pairs| {
                                        pairs
                                            .iter()
                                            .filter_map(|p| {
                                                Some((
                                                    p.get("index")?.as_usize()?,
                                                    p.get("score")?.as_f64()? as f32,
                                                ))
                                            })
                                            .collect()
                                    })
                                    .unwrap_or_default()
                            })
                            .collect()
                    })
                    .unwrap_or_default();
                let floats = |v: &Json| -> Vec<f32> {
                    v.as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
                        .unwrap_or_default()
                };
                let scores = v.get("scores").and_then(|rows| {
                    rows.as_arr()
                        .map(|rs| rs.iter().flat_map(|r| floats(r)).collect::<Vec<f32>>())
                });
                Response::Scores(Box::new(ScoreResponse {
                    id,
                    scorer: v.req("scorer")?.as_str().unwrap_or_default().to_string(),
                    m: v.req("m")?.as_usize().unwrap_or(0),
                    n,
                    top,
                    scores,
                    self_influence: v.get("self_influence").map(floats),
                    classes: v.get("classes").and_then(|c| {
                        c.as_arr()
                            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    }),
                    coverage: CoverageInfo::from_json(v.req("coverage")?)?,
                    elapsed_ms: v.get("elapsed_ms").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    epoch: v.get("epoch").and_then(|x| x.as_u64()).unwrap_or(0),
                }))
            }
            "stats" => Response::Stats {
                id,
                stats: v.req("stats")?.clone(),
            },
            "pong" => Response::Pong { id },
            "shutting_down" => Response::ShuttingDown { id },
            "reloaded" => Response::Reloaded {
                id,
                epoch: v.get("epoch").and_then(|x| x.as_u64()).unwrap_or(0),
                store: v
                    .get("store")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
            },
            "error" => Response::Error {
                id,
                kind: ErrorKind::parse(v.req("kind")?.as_str().unwrap_or_default())?,
                message: v
                    .get("message")
                    .and_then(|x| x.as_str())
                    .unwrap_or_default()
                    .to_string(),
            },
            other => bail!("unknown response type {other:?}"),
        })
    }

    /// One-line wire frame (compact JSON + newline).
    pub fn to_line(&self) -> String {
        let mut s = self.to_json().to_string_compact();
        s.push('\n');
        s
    }
}

fn check_version(v: &Json) -> Result<()> {
    let got = v.req("v")?.as_u64().unwrap_or(0);
    ensure!(
        got == PROTO_VERSION,
        "protocol version mismatch: peer speaks v{got}, this build speaks v{PROTO_VERSION}"
    );
    Ok(())
}

/// Write one frame and flush (NDJSON framing is line-buffered).
pub fn write_frame(w: &mut impl Write, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Why a frame could not be produced (see [`FrameReader::poll_frame`]).
/// A real enum rather than an opaque error chain so the session can count
/// oversized frames separately from parse failures.
#[derive(Debug)]
pub enum FrameError {
    /// The frame exceeded the byte bound without producing a newline.
    TooLarge { limit: usize },
    /// The frame arrived but was not valid UTF-8 / JSON.
    Parse(anyhow::Error),
    /// The transport failed mid-read (not a timeout — timeouts are
    /// [`FramePoll::Pending`]).
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooLarge { limit } => {
                write!(f, "frame exceeds the {limit}-byte bound without a newline")
            }
            FrameError::Parse(e) => write!(f, "{e:#}"),
            FrameError::Io(e) => write!(f, "reading frame: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// One poll step of a [`FrameReader`].
#[derive(Debug)]
pub enum FramePoll {
    /// A complete frame was parsed.
    Frame(Json),
    /// The peer closed the stream with no frame pending.
    Eof,
    /// The read timed out (`WouldBlock` / `TimedOut`) before a full frame
    /// arrived. Already-received bytes are retained — poll again.
    Pending,
}

/// Incremental NDJSON frame reader that survives read timeouts and bounds
/// per-frame memory.
///
/// `BufRead::read_until` appends whatever bytes arrived before an error to
/// the caller's buffer, so a persistent buffer turns a per-connection read
/// timeout into a *tick*: a slow client's half-frame accumulates across
/// polls instead of desyncing the stream, and the session loop gets
/// control back between polls to check idle/shutdown state. A `Take`
/// bound on every poll caps how many bytes one frame may ever buffer
/// (see [`FrameError::TooLarge`]).
pub struct FrameReader<R: BufRead> {
    r: R,
    buf: Vec<u8>,
}

impl<R: BufRead> FrameReader<R> {
    pub fn new(r: R) -> Self {
        Self { r, buf: Vec::new() }
    }

    /// Bytes of a partial frame currently buffered.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Advance toward the next frame, reading at most `max_bytes + 1`
    /// bytes total for it (one byte past the bound distinguishes an
    /// exactly-max frame from an oversized one).
    pub fn poll_frame(&mut self, max_bytes: usize) -> std::result::Result<FramePoll, FrameError> {
        fn parse(line: Vec<u8>) -> std::result::Result<Option<FramePoll>, FrameError> {
            let text = std::str::from_utf8(&line)
                .map_err(|_| FrameError::Parse(anyhow!("frame is not valid UTF-8")))?;
            let trimmed = text.trim();
            if trimmed.is_empty() {
                return Ok(None); // blank keep-alive line
            }
            Json::parse(trimmed)
                .map(|v| Some(FramePoll::Frame(v)))
                .map_err(FrameError::Parse)
        }
        loop {
            let budget = (max_bytes + 1).saturating_sub(self.buf.len()) as u64;
            let n = match (&mut self.r).take(budget).read_until(b'\n', &mut self.buf) {
                Ok(n) => n,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    return Ok(FramePoll::Pending);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(FrameError::Io(e)),
            };
            if self.buf.last() == Some(&b'\n') {
                match parse(std::mem::take(&mut self.buf))? {
                    Some(frame) => return Ok(frame),
                    None => continue, // tolerate blank keep-alive lines
                }
            }
            if self.buf.len() > max_bytes {
                return Err(FrameError::TooLarge { limit: max_bytes });
            }
            if n == 0 {
                // True EOF (the budget can only run dry past the bound,
                // handled above). An unterminated final line still parses,
                // matching the historical `read_frame` behaviour.
                if self.buf.is_empty() {
                    return Ok(FramePoll::Eof);
                }
                return match parse(std::mem::take(&mut self.buf))? {
                    Some(frame) => Ok(frame),
                    None => Ok(FramePoll::Eof),
                };
            }
        }
    }
}

/// Read one NDJSON frame, bounded at [`MAX_FRAME_BYTES`]; `Ok(None)` on a
/// clean EOF, `Err` on a parse failure, an oversized frame
/// ([`FrameError::TooLarge`]), or a read timeout on a stream with a read
/// deadline set (`grass query --timeout-ms`).
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<Json>> {
    let mut fr = FrameReader::new(r);
    match fr.poll_frame(MAX_FRAME_BYTES)? {
        FramePoll::Frame(v) => Ok(Some(v)),
        FramePoll::Eof => Ok(None),
        FramePoll::Pending => bail!("timed out waiting for a frame"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_all_variants() {
        let reqs = vec![
            Request::Score(ScoreRequest {
                id: 7,
                scorer: "if".into(),
                top_k: 5,
                include_scores: true,
                self_influence: true,
                deadline_ms: Some(250),
                queries: QueryPayload::Synth { m: 4 },
            }),
            Request::Score(ScoreRequest {
                id: 8,
                scorer: "graddot".into(),
                top_k: 3,
                include_scores: false,
                self_influence: false,
                deadline_ms: None,
                queries: QueryPayload::Compressed {
                    m: 2,
                    rows: vec![1.0, -2.5, 0.25, 3.0],
                },
            }),
            Request::Score(ScoreRequest {
                id: 9,
                scorer: "if".into(),
                top_k: 1,
                include_scores: false,
                self_influence: false,
                deadline_ms: Some(0),
                queries: QueryPayload::Raw {
                    m: 1,
                    rows: vec![0.5; 8],
                },
            }),
            Request::Stats { id: 1 },
            Request::Ping { id: 2 },
            Request::Shutdown { id: 3 },
            Request::Reload { id: 4, store: None },
            Request::Reload {
                id: 5,
                store: Some("/tmp/other_store".into()),
            },
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
            let back = Request::from_json(&Json::parse(line.trim()).unwrap()).unwrap();
            assert_eq!(req, back);
        }
    }

    #[test]
    fn response_roundtrip_all_variants() {
        let resps = vec![
            Response::Scores(Box::new(ScoreResponse {
                id: 7,
                scorer: "if".into(),
                m: 2,
                n: 3,
                top: vec![vec![(2, 1.5), (0, 0.25)], vec![(1, -0.5)]],
                scores: Some(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                self_influence: Some(vec![0.5, 0.75]),
                classes: Some(vec![3, 1]),
                coverage: CoverageInfo {
                    rows_total: 3,
                    rows_scored: 3,
                    quarantined: vec![],
                    retries_attempted: 0,
                },
                elapsed_ms: 1.5,
                epoch: 3,
            })),
            Response::Stats {
                id: 1,
                stats: Json::obj(vec![("requests", Json::Num(4.0))]),
            },
            Response::Pong { id: 2 },
            Response::ShuttingDown { id: 3 },
            Response::Reloaded {
                id: 5,
                epoch: 2,
                store: "/tmp/store".into(),
            },
            Response::Error {
                id: 4,
                kind: ErrorKind::Overloaded,
                message: "queue full".into(),
            },
        ];
        for resp in resps {
            let back = Response::from_json(&Json::parse(resp.to_line().trim()).unwrap()).unwrap();
            assert_eq!(resp, back);
        }
    }

    #[test]
    fn degraded_coverage_roundtrips() {
        let cov = CoverageInfo {
            rows_total: 512,
            rows_scored: 480,
            quarantined: vec![2],
            retries_attempted: 3,
        };
        assert!(cov.is_degraded());
        let j = cov.to_json();
        assert_eq!(j.get("degraded").and_then(|x| x.as_bool()), Some(true));
        assert_eq!(CoverageInfo::from_json(&j).unwrap(), cov);
    }

    #[test]
    fn version_mismatch_rejected() {
        let v = Json::parse(r#"{"v":2,"type":"ping","id":1}"#).unwrap();
        let err = Request::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        let kinds = [
            ErrorKind::Overloaded,
            ErrorKind::DeadlineExceeded,
            ErrorKind::BadRequest,
            ErrorKind::Internal,
        ];
        for k in kinds {
            assert_eq!(ErrorKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(ErrorKind::Overloaded.is_shed());
        assert!(ErrorKind::DeadlineExceeded.is_shed());
        assert!(!ErrorKind::BadRequest.is_shed());
    }

    #[test]
    fn frame_io_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Request::Ping { id: 9 }.to_line()).unwrap();
        write_frame(&mut buf, "\n").unwrap();
        write_frame(&mut buf, &Request::Stats { id: 10 }.to_line()).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        let a = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(Request::from_json(&a).unwrap(), Request::Ping { id: 9 });
        let b = read_frame(&mut r).unwrap().unwrap();
        assert_eq!(Request::from_json(&b).unwrap(), Request::Stats { id: 10 });
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_is_a_typed_error() {
        // A newline-terminated line past the bound…
        let line = format!("{}\n", "x".repeat(64));
        let mut r = std::io::BufReader::new(line.as_bytes());
        let err = FrameReader::new(&mut r).poll_frame(16).unwrap_err();
        assert!(matches!(err, FrameError::TooLarge { limit: 16 }), "{err}");
        // …and an unterminated one: same typed error, no unbounded buffering.
        let blob = "y".repeat(1000);
        let mut r = std::io::BufReader::new(blob.as_bytes());
        let err = FrameReader::new(&mut r).poll_frame(16).unwrap_err();
        assert!(matches!(err, FrameError::TooLarge { .. }), "{err}");
        // A frame of exactly the bound still parses.
        let exact = format!("{}\n", r#"{"v":1,"type":"ping","id":1}"#);
        let max = exact.trim().len();
        let mut r = std::io::BufReader::new(exact.as_bytes());
        match FrameReader::new(&mut r).poll_frame(max + 1).unwrap() {
            FramePoll::Frame(v) => {
                assert_eq!(Request::from_json(&v).unwrap(), Request::Ping { id: 1 });
            }
            other => panic!("expected a frame, got {other:?}"),
        }
    }

    #[test]
    fn frame_reader_retains_partial_bytes_across_polls() {
        // Simulate a timeout mid-frame: a reader that yields half the
        // frame, then a TimedOut error, then the rest.
        struct Dribble {
            parts: Vec<Vec<u8>>,
            next: usize,
        }
        impl std::io::Read for Dribble {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                if self.next >= self.parts.len() {
                    return Ok(0);
                }
                if self.parts[self.next].is_empty() {
                    self.next += 1;
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "injected timeout",
                    ));
                }
                let part = &self.parts[self.next];
                let n = part.len().min(buf.len());
                buf[..n].copy_from_slice(&part[..n]);
                let rest = part[n..].to_vec();
                if rest.is_empty() {
                    self.next += 1;
                } else {
                    self.parts[self.next] = rest;
                }
                Ok(n)
            }
        }
        let line = Request::Ping { id: 42 }.to_line();
        let (a, b) = line.as_bytes().split_at(line.len() / 2);
        let r = Dribble {
            parts: vec![a.to_vec(), vec![], b.to_vec()],
            next: 0,
        };
        let mut fr = FrameReader::new(std::io::BufReader::with_capacity(4, r));
        let first = fr.poll_frame(MAX_FRAME_BYTES).unwrap();
        assert!(matches!(first, FramePoll::Pending), "{first:?}");
        assert!(fr.buffered() > 0, "partial bytes must survive the timeout");
        match fr.poll_frame(MAX_FRAME_BYTES).unwrap() {
            FramePoll::Frame(v) => {
                assert_eq!(Request::from_json(&v).unwrap(), Request::Ping { id: 42 });
            }
            other => panic!("expected the completed frame, got {other:?}"),
        }
        assert!(matches!(
            fr.poll_frame(MAX_FRAME_BYTES).unwrap(),
            FramePoll::Eof
        ));
    }
}
