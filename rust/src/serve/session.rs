//! Per-connection protocol loop: read NDJSON frames, run admission, hand
//! scoring jobs to the worker pool, write replies. One thread per
//! connection; all heavy work happens on the bounded worker pool, so a
//! slow client costs one blocked thread, not a scoring slot.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};

use crate::serve::admission::Deadline;
use crate::serve::proto::{self, ErrorKind, Request, Response};
use crate::serve::server::{Job, ServerState};

pub(crate) fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    let peer_read = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(peer_read);
    let mut writer = BufWriter::new(stream);
    loop {
        if state.shutdown.load(Ordering::Acquire) {
            return;
        }
        let frame = match proto::read_frame(&mut reader) {
            Ok(Some(v)) => v,
            Ok(None) => return, // clean EOF
            Err(e) => {
                state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                let resp = Response::Error {
                    id: 0,
                    kind: ErrorKind::BadRequest,
                    message: format!("unparseable frame: {e:#}"),
                };
                let _ = proto::write_frame(&mut writer, &resp.to_line());
                return; // desynced stream: drop the connection
            }
        };
        let req = match Request::from_json(&frame) {
            Ok(r) => r,
            Err(e) => {
                state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                let id = frame.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
                let resp = Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!("{e:#}"),
                };
                if proto::write_frame(&mut writer, &resp.to_line()).is_err() {
                    return;
                }
                continue;
            }
        };
        state.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let resp = match req {
            Request::Ping { id } => Response::Pong { id },
            Request::Stats { id } => Response::Stats {
                id,
                stats: state.stats_json(),
            },
            Request::Shutdown { id } => {
                let _ = proto::write_frame(&mut writer, &Response::ShuttingDown { id }.to_line());
                state.begin_shutdown();
                return;
            }
            Request::Score(score) => {
                let deadline = Deadline::new(score.deadline_ms, state.cfg.deadline_ms);
                match state.admission.try_admit() {
                    None => {
                        state.metrics.overloaded.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            id: score.id,
                            kind: ErrorKind::Overloaded,
                            message: format!(
                                "queue full ({} in flight, bound {})",
                                state.admission.depth(),
                                state.admission.max_in_flight()
                            ),
                        }
                    }
                    Some(ticket) => {
                        let (reply_tx, reply_rx) = mpsc::channel();
                        let id = score.id;
                        let job = Job {
                            req: score,
                            deadline,
                            ticket,
                            reply: reply_tx,
                        };
                        let enqueued = match state.jobs.lock().unwrap().as_ref() {
                            Some(tx) => tx.send(job).is_ok(),
                            None => false,
                        };
                        if enqueued {
                            match reply_rx.recv() {
                                Ok(resp) => resp,
                                Err(_) => Response::Error {
                                    id,
                                    kind: ErrorKind::Internal,
                                    message: "worker dropped the request".to_string(),
                                },
                            }
                        } else {
                            Response::Error {
                                id,
                                kind: ErrorKind::Internal,
                                message: "daemon is shutting down".to_string(),
                            }
                        }
                    }
                }
            }
        };
        if proto::write_frame(&mut writer, &resp.to_line()).is_err() {
            return;
        }
    }
}
