//! Per-connection protocol loop: read NDJSON frames, run admission, hand
//! scoring jobs to the worker pool, write replies. One thread per
//! connection; all heavy work happens on the bounded worker pool, so a
//! slow client costs one blocked thread, not a scoring slot.
//!
//! Slow-client protection: reads tick on a short timeout (so the loop
//! observes the shutdown flag between frames), writes carry a bounded
//! timeout, and a connection that completes no frame for `--idle-ms` is
//! reaped — a byte-dribbling peer cannot hold a session thread forever.
//! Frames are bounded by [`proto::MAX_FRAME_BYTES`]; parse failures and
//! oversized frames are counted separately and answered with one typed
//! error before the desynced stream is dropped.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::serve::admission::Deadline;
use crate::serve::proto::{self, ErrorKind, FramePoll, FrameReader, Request, Response};
use crate::serve::server::{Job, ServerState};

/// Read-timeout tick: how often an idle session re-checks the shutdown
/// flag and its idle budget.
const SESSION_TICK: Duration = Duration::from_millis(100);

/// Decrements the active-connections gauge on every exit path.
struct ConnGuard(Arc<ServerState>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.metrics.conn_closed();
    }
}

pub(crate) fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    state.metrics.conn_opened();
    let _guard = ConnGuard(state.clone());
    let peer_read = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = peer_read.set_read_timeout(Some(SESSION_TICK));
    // A peer that stops reading its replies blocks the writer at most
    // this long; the session then drops the connection.
    let write_ms = state.cfg.idle_ms.max(1_000);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(write_ms)));
    let mut frames = FrameReader::new(BufReader::new(peer_read));
    let mut writer = BufWriter::new(stream);
    let idle = (state.cfg.idle_ms > 0).then(|| Duration::from_millis(state.cfg.idle_ms));
    let mut last_frame = Instant::now();
    loop {
        if state.shutdown.load(Ordering::Acquire) {
            return;
        }
        let frame = match frames.poll_frame(proto::MAX_FRAME_BYTES) {
            Ok(FramePoll::Frame(v)) => {
                last_frame = Instant::now();
                v
            }
            Ok(FramePoll::Eof) => return, // clean EOF
            Ok(FramePoll::Pending) => {
                if let Some(budget) = idle {
                    if last_frame.elapsed() >= budget {
                        state.metrics.reaped_idle.fetch_add(1, Ordering::Relaxed);
                        let resp = Response::Error {
                            id: 0,
                            kind: ErrorKind::BadRequest,
                            message: format!(
                                "no complete frame in {} ms; closing idle connection",
                                budget.as_millis()
                            ),
                        };
                        let _ = proto::write_frame(&mut writer, &resp.to_line());
                        return;
                    }
                }
                continue;
            }
            Err(e) => {
                if matches!(e, proto::FrameError::TooLarge { .. }) {
                    state
                        .metrics
                        .bad_frames_oversized
                        .fetch_add(1, Ordering::Relaxed);
                } else {
                    state
                        .metrics
                        .bad_frames_parse
                        .fetch_add(1, Ordering::Relaxed);
                }
                state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                let resp = Response::Error {
                    id: 0,
                    kind: ErrorKind::BadRequest,
                    message: format!("unparseable frame: {e}"),
                };
                let _ = proto::write_frame(&mut writer, &resp.to_line());
                return; // desynced stream: drop the connection
            }
        };
        let req = match Request::from_json(&frame) {
            Ok(r) => r,
            Err(e) => {
                state.metrics.bad_requests.fetch_add(1, Ordering::Relaxed);
                let id = frame.get("id").and_then(|x| x.as_u64()).unwrap_or(0);
                let resp = Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!("{e:#}"),
                };
                if proto::write_frame(&mut writer, &resp.to_line()).is_err() {
                    return;
                }
                continue;
            }
        };
        state.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let resp = match req {
            Request::Ping { id } => Response::Pong { id },
            Request::Stats { id } => Response::Stats {
                id,
                stats: state.stats_json(),
            },
            Request::Reload { id, store } => state.try_reload(id, store.as_deref()),
            Request::Shutdown { id } => {
                let _ = proto::write_frame(&mut writer, &Response::ShuttingDown { id }.to_line());
                state.begin_shutdown("shutdown request");
                return;
            }
            Request::Score(score) => {
                let deadline = Deadline::new(score.deadline_ms, state.cfg.deadline_ms);
                match state.admission.try_admit() {
                    None => {
                        state.metrics.overloaded.fetch_add(1, Ordering::Relaxed);
                        Response::Error {
                            id: score.id,
                            kind: ErrorKind::Overloaded,
                            message: format!(
                                "queue full ({} in flight, bound {})",
                                state.admission.depth(),
                                state.admission.max_in_flight()
                            ),
                        }
                    }
                    Some(ticket) => {
                        let (reply_tx, reply_rx) = mpsc::channel();
                        let id = score.id;
                        let job = Job {
                            req: score,
                            deadline,
                            ticket,
                            reply: reply_tx,
                        };
                        let enqueued = match state
                            .jobs
                            .lock()
                            .unwrap_or_else(|p| p.into_inner())
                            .as_ref()
                        {
                            Some(tx) => tx.send(job).is_ok(),
                            None => false,
                        };
                        if enqueued {
                            match reply_rx.recv() {
                                Ok(resp) => resp,
                                Err(_) => Response::Error {
                                    id,
                                    kind: ErrorKind::Internal,
                                    message: "worker dropped the request".to_string(),
                                },
                            }
                        } else {
                            Response::Error {
                                id,
                                kind: ErrorKind::Internal,
                                message: "daemon is shutting down".to_string(),
                            }
                        }
                    }
                }
            }
        };
        if proto::write_frame(&mut writer, &resp.to_line()).is_err() {
            return;
        }
    }
}
