//! Attribution serving: the long-running daemon behind `grass serve`.
//!
//! Batch attribution re-pays process startup, store open, bank
//! construction, and precond-artifact load on every invocation. This
//! module turns that cost into one-time daemon state:
//!
//! - [`server`] — the supervised worker pool and the drain sequence;
//!   [`ServeConfig`] / [`spawn`] / [`run`] are the public surface.
//! - [`hot`](self) — epoch-versioned hot state (store opened once per
//!   epoch, engines ingested once per epoch); the `reload` request swaps
//!   epochs atomically while in-flight requests finish on the old one.
//! - [`proto`] — the versioned newline-delimited-JSON wire protocol
//!   (`score` / `stats` / `ping` / `reload` / `shutdown` requests; typed
//!   error replies; frames bounded by [`proto::MAX_FRAME_BYTES`]).
//!   `grass query` is the reference client.
//! - [`admission`] — queue-depth load shedding ([`Admission`]) and
//!   per-request latency budgets ([`admission::Deadline`]): a full queue
//!   answers `Overloaded`, a stale request answers `DeadlineExceeded`, and
//!   the daemon keeps serving either way.
//! - [`signal`] — std-only SIGTERM/SIGINT capture (CLI path only); a
//!   signal and a protocol `shutdown` request are two doors into the same
//!   draining shutdown.
//! - [`shard_cache`] — [`ShardCache`], the warm LRU shard-byte pool with
//!   sequential prefetch. It attaches to any
//!   [`StoreReader`](crate::store::StoreReader), so the batch
//!   `grass attribute --shard-cache` path reuses it too.
//! - [`metrics`] — the [`Metrics`] registry (request counters, p50/p95/p99
//!   latency, worker panics/respawns, connection gauge, reloads), served
//!   by the `stats` request and dumped on graceful shutdown.
//!
//! Degradation model: scoring streams through the existing
//! [`ReadGuard`](crate::store::ReadGuard) retry/quarantine layer, a
//! runtime circuit breaker quarantines shards that keep failing reads
//! (cleared by `reload`), worker panics answer their client with a typed
//! `internal` error and the worker is respawned — a corrupt shard, a slow
//! client, or a panicking scorer degrades one reply, never the daemon.

pub mod admission;
pub(crate) mod hot;
pub mod metrics;
pub mod proto;
pub mod server;
pub(crate) mod session;
pub mod shard_cache;
pub mod signal;

pub use admission::Admission;
pub use metrics::{LatencySummary, Metrics};
pub use proto::{ErrorKind, QueryPayload, Request, Response, PROTO_VERSION};
pub use server::{run, spawn, ServeConfig, ServerHandle};
pub use shard_cache::{CacheStats, ShardCache};
