//! Attribution serving: the long-running daemon behind `grass serve`.
//!
//! Batch attribution re-pays process startup, store open, bank
//! construction, and precond-artifact load on every invocation. This
//! module turns that cost into one-time daemon state:
//!
//! - [`server`] — hot-state construction (store opened once, engines
//!   ingested once) and the bounded worker pool; [`ServeConfig`] /
//!   [`spawn`] / [`run`] are the public surface.
//! - [`proto`] — the versioned newline-delimited-JSON wire protocol
//!   (`score` / `stats` / `ping` / `shutdown` requests; typed error
//!   replies). `grass query` is the reference client.
//! - [`admission`] — queue-depth load shedding ([`Admission`]) and
//!   per-request latency budgets ([`admission::Deadline`]): a full queue
//!   answers `Overloaded`, a stale request answers `DeadlineExceeded`, and
//!   the daemon keeps serving either way.
//! - [`shard_cache`] — [`ShardCache`], the warm LRU shard-byte pool with
//!   sequential prefetch. It attaches to any
//!   [`StoreReader`](crate::store::StoreReader), so the batch
//!   `grass attribute --shard-cache` path reuses it too.
//! - [`metrics`] — the [`Metrics`] registry (request counters, p50/p95/p99
//!   latency, rows scored), served by the `stats` request and dumped on
//!   graceful shutdown.
//!
//! Degradation model: scoring streams through the existing
//! [`ReadGuard`](crate::store::ReadGuard) retry/quarantine layer, so a
//! corrupt shard degrades the *response coverage* of affected replies
//! instead of killing the daemon.

pub mod admission;
pub mod metrics;
pub mod proto;
pub mod server;
pub(crate) mod session;
pub mod shard_cache;

pub use admission::Admission;
pub use metrics::{LatencySummary, Metrics};
pub use proto::{ErrorKind, QueryPayload, Request, Response, PROTO_VERSION};
pub use server::{run, spawn, ServeConfig, ServerHandle};
pub use shard_cache::{CacheStats, ShardCache};
