//! Epoch-versioned hot state for the serving daemon.
//!
//! Everything a request needs to score — store meta, compressor bank,
//! ingested engines, the warm shard cache, and the shared read log — lives
//! in one immutable [`HotState`] behind an `Arc`. Workers clone the `Arc`
//! per job, so a hot reload can build a replacement state in the
//! background and atomically swap it in while in-flight requests finish on
//! the epoch they started with; the old state (and its cache/prefetcher)
//! drops when the last in-flight reference does. Each build gets a fresh
//! [`ReadLog`], which is also what clears the runtime circuit breaker on
//! reload.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context};

use crate::attrib::{
    from_spec, AttributionSpec, Attributor, PrecondArtifact, PrecondSpec, StreamOpts,
};
use crate::coordinator::CompressorBank;
use crate::data::synthgrad::SYNTH_MODEL;
use crate::serve::server::ServeConfig;
use crate::serve::shard_cache::ShardCache;
use crate::store::{ReadLog, RetryPolicy, StoreMeta, StoreReader};
use crate::Result;

/// Canonical scorer id (the registry aliases collapsed), so config keys
/// and request keys always meet.
pub(crate) fn canon_scorer(s: &str) -> &str {
    match s {
        "influence" => "if",
        "dot" => "graddot",
        "bw" => "blockwise",
        other => other,
    }
}

/// One resident scorer: ingested once per epoch, shared by all workers.
pub(crate) struct Engine {
    pub attributor: Box<dyn Attributor>,
    pub fim_rows: usize,
    pub describe: String,
}

/// One epoch of servable state. Immutable once built; swapped whole.
pub(crate) struct HotState {
    /// Monotonic epoch: 1 at startup, +1 per completed reload.
    pub epoch: u64,
    /// The store directory this epoch serves (reload may retarget it).
    pub dir: PathBuf,
    pub meta: StoreMeta,
    pub bank: CompressorBank,
    pub engines: BTreeMap<String, Engine>,
    pub cache: Option<Arc<ShardCache>>,
    pub artifact_loaded: bool,
    /// Read log shared by every engine of this epoch — quarantine set,
    /// retry counts, and the armed circuit breaker.
    pub read_log: Arc<ReadLog>,
}

impl HotState {
    /// Build one epoch of hot state against the store at `dir`: one store
    /// open, one bank rebuild, one artifact load, one ingest per scorer.
    ///
    /// `expect` carries the previous epoch's meta during a reload: the new
    /// store must describe the *same attribution space* (method spec,
    /// seed, sketch width, gradient geometry) or the reload is refused
    /// descriptively before any expensive ingest runs. Row count, payload
    /// dtype, and density may change — that is what reload is for
    /// (appended or re-quantized stores).
    pub fn build(
        cfg: &ServeConfig,
        dir: &Path,
        epoch: u64,
        expect: Option<&StoreMeta>,
    ) -> Result<Self> {
        ensure!(!cfg.scorers.is_empty(), "serve needs at least one --scorer");
        let mut reader = StoreReader::open(dir)
            .with_context(|| format!("opening store at {}", dir.display()))?;
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(plan) = &cfg.faults {
            reader.inject_faults(plan.clone());
        }
        if let Some(old) = expect {
            check_reload_compat(old, &reader.meta)
                .with_context(|| format!("store at {}", dir.display()))?;
        }
        if cfg.verify {
            let report = reader.verify_checksums()?;
            if !report.all_ok() {
                let bad: Vec<usize> = report
                    .shards
                    .iter()
                    .filter(|(_, s)| !s.is_ok())
                    .map(|(i, _)| *i)
                    .collect();
                ensure!(
                    cfg.skip_corrupt,
                    "store at {} failed verification (bad shards: {bad:?}); refusing to serve — \
                     pass --skip-corrupt to serve degraded",
                    dir.display()
                );
                if !cfg.quiet {
                    eprintln!(
                        "warning: serving degraded — verification flagged shards {bad:?} at {}",
                        dir.display()
                    );
                }
            }
        }
        let cache = if cfg.cache_bytes > 0 {
            let cache = Arc::new(ShardCache::new(cfg.cache_bytes));
            // The prefetcher clones the reader *before* the cache attaches:
            // it must read bytes from disk (through the fault hooks), not
            // look itself up.
            cache.spawn_prefetcher_with(reader.clone());
            reader.attach_cache(cache.clone());
            Some(cache)
        } else {
            None
        };
        let shapes = reader.meta.shapes();
        ensure!(
            shapes.p > 0 || !shapes.layers.is_empty(),
            "store at {} records no gradient geometry (pre-redesign cache?); re-run `grass cache`",
            dir.display()
        );
        let spec = reader.meta.spec()?;
        let seed = reader.meta.seed;
        let bank = spec.build_bank(&shapes, seed)?;
        ensure!(
            bank.output_dim() == reader.meta.k,
            "rebuilt bank emits {} columns but the store has k = {}",
            bank.output_dim(),
            reader.meta.k
        );
        let model = reader.meta.model.as_str();
        ensure!(
            model == SYNTH_MODEL || model.is_empty(),
            "serving store model '{model}' needs the PJRT runtime per query; only synthetic-model \
             stores are servable today"
        );

        let artifact = if cfg.use_artifact {
            match PrecondArtifact::load_if_present(dir)? {
                Some(a) => {
                    a.validate_store(&reader.meta)?;
                    Some(Arc::new(a))
                }
                None => None,
            }
        } else {
            None
        };
        let artifact_loaded = artifact.is_some();

        let base_opts = StreamOpts {
            mem_budget: cfg.mem_budget,
            workers: cfg.workers.max(1),
            retry: RetryPolicy {
                retries: cfg.retries,
                backoff: Duration::from_millis(cfg.retry_backoff_ms),
                seed,
            },
            skip_corrupt: cfg.skip_corrupt,
            breaker: cfg.breaker,
            ..StreamOpts::default()
        };
        let read_log = base_opts.log.clone();

        let mut engines = BTreeMap::new();
        for name in &cfg.scorers {
            let scorer = canon_scorer(name).to_string();
            if engines.contains_key(&scorer) {
                continue;
            }
            let pspec = match &cfg.precond {
                Some(s) => PrecondSpec::parse_with(s, cfg.damping)?,
                None => PrecondSpec::default_for_scorer(&scorer, cfg.damping),
            };
            let mut opts = base_opts.clone();
            if pspec.needs_fim() {
                opts.artifact = artifact.clone();
            }
            let mut aspec = AttributionSpec::new(&scorer, spec.clone(), seed);
            aspec.damping = cfg.damping;
            aspec.layout = bank.layer_dims();
            aspec.precond = Some(pspec);
            let mut attributor = from_spec(&aspec)
                .with_context(|| format!("building serve engine for scorer '{scorer}'"))?;
            attributor
                .cache_stream(&reader, &opts)
                .with_context(|| format!("ingesting store for scorer '{scorer}'"))?;
            let pstats = attributor.precond_stats();
            engines.insert(
                scorer,
                Engine {
                    attributor,
                    fim_rows: pstats.fim_rows,
                    describe: pstats.describe,
                },
            );
        }

        Ok(HotState {
            epoch,
            dir: dir.to_path_buf(),
            meta: reader.meta.clone(),
            bank,
            engines,
            cache,
            artifact_loaded,
            read_log,
        })
    }
}

/// Refuse a reload that would change the attribution space under the
/// clients' feet. Same method spec + seed + sketch width + gradient
/// geometry are required; `n`, payload dtype, and density are free to
/// change (appended / re-quantized stores are the point of reload).
fn check_reload_compat(old: &StoreMeta, new: &StoreMeta) -> Result<()> {
    ensure!(
        new.method == old.method,
        "reload would change the compression method ('{}' → '{}'); \
         start a fresh daemon for a different method spec",
        old.method,
        new.method
    );
    ensure!(
        new.seed == old.seed,
        "reload would change the sketch seed ({} → {}); scores would be \
         incomparable across the swap",
        old.seed,
        new.seed
    );
    ensure!(
        new.k == old.k,
        "reload would change the sketch width (k = {} → {})",
        old.k,
        new.k
    );
    ensure!(
        new.input_dim == old.input_dim && new.layer_dims == old.layer_dims,
        "reload would change the gradient geometry (input_dim {} → {}, layers {:?} → {:?})",
        old.input_dim,
        new.input_dim,
        old.layer_dims,
        new.layer_dims
    );
    ensure!(
        new.model == old.model,
        "reload would change the gradient model ('{}' → '{}')",
        old.model,
        new.model
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(k: usize, seed: u64) -> StoreMeta {
        StoreMeta {
            k,
            n: 64,
            shard_rows: 8,
            method: "sjlt:k=32".into(),
            seed,
            model: "synth".into(),
            input_dim: 128,
            layer_dims: vec![],
            density: 1.0,
            dtype: crate::store::PayloadDtype::F32,
        }
    }

    #[test]
    fn compat_allows_growth_and_requant_but_not_spec_changes() {
        let old = meta(32, 7);
        // Appended rows + a different payload dtype are fine.
        let mut grown = meta(32, 7);
        grown.n = 128;
        grown.dtype = crate::store::PayloadDtype::F16;
        grown.density = 0.5;
        assert!(check_reload_compat(&old, &grown).is_ok());
        // Changed seed / width / method / geometry are refused.
        let mut bad_seed = meta(32, 8);
        bad_seed.n = 64;
        let err = check_reload_compat(&old, &bad_seed).unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");
        let bad_k = meta(16, 7);
        assert!(check_reload_compat(&old, &bad_k).is_err());
        let mut bad_method = meta(32, 7);
        bad_method.method = "edge".into();
        let err = check_reload_compat(&old, &bad_method).unwrap_err();
        assert!(err.to_string().contains("method"), "{err}");
        let mut bad_geom = meta(32, 7);
        bad_geom.input_dim = 256;
        assert!(check_reload_compat(&old, &bad_geom).is_err());
        let mut bad_model = meta(32, 7);
        bad_model.model = "real".into();
        assert!(check_reload_compat(&old, &bad_model).is_err());
    }
}
