//! Daemon metrics registry: request counters, latency percentiles, rows
//! scored. Exposed live via the `stats` request and dumped once on
//! shutdown. All counters are lock-free; only the latency reservoir takes
//! a short mutex.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Rolling latency reservoir size — enough for stable p99 at smoke scale
/// without unbounded growth on long-lived daemons.
const LAT_CAP: usize = 4096;

/// Percentile summary over the recorded latency reservoir.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    /// Total latencies ever recorded (the reservoir keeps the last
    /// [`LAT_CAP`]).
    pub count: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

struct Reservoir {
    /// Last `LAT_CAP` request latencies, microseconds, ring-ordered.
    ring: Vec<u64>,
    next: usize,
    total: u64,
}

/// Live metrics for one daemon instance.
pub struct Metrics {
    started: Instant,
    pub requests: AtomicU64,
    pub scored: AtomicU64,
    pub overloaded: AtomicU64,
    pub deadline_exceeded: AtomicU64,
    pub bad_requests: AtomicU64,
    /// Bad frames that failed to parse (malformed JSON / bad UTF-8).
    pub bad_frames_parse: AtomicU64,
    /// Bad frames rejected for exceeding the per-frame byte bound.
    pub bad_frames_oversized: AtomicU64,
    pub internal_errors: AtomicU64,
    pub degraded_responses: AtomicU64,
    /// Worker panics caught by the supervisor (each produced a typed
    /// `Internal` reply, never a silent drop).
    pub panics: AtomicU64,
    /// Workers respawned after a panic killed their thread.
    pub respawns: AtomicU64,
    /// Completed hot store reloads (epoch swaps).
    pub reloads: AtomicU64,
    /// Connections accepted over the daemon's lifetime.
    pub connections_opened: AtomicU64,
    /// Currently-open connections (gauge).
    pub connections_active: AtomicU64,
    /// Connections closed by the idle reaper (slow/stalled peers).
    pub reaped_idle: AtomicU64,
    /// Training rows streamed through scoring passes.
    pub rows_scored: AtomicU64,
    lat: Mutex<Reservoir>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            scored: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            deadline_exceeded: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            bad_frames_parse: AtomicU64::new(0),
            bad_frames_oversized: AtomicU64::new(0),
            internal_errors: AtomicU64::new(0),
            degraded_responses: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            connections_opened: AtomicU64::new(0),
            connections_active: AtomicU64::new(0),
            reaped_idle: AtomicU64::new(0),
            rows_scored: AtomicU64::new(0),
            lat: Mutex::new(Reservoir {
                ring: Vec::new(),
                next: 0,
                total: 0,
            }),
        }
    }

    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Track one accepted connection (pair with [`Metrics::conn_closed`]).
    pub fn conn_opened(&self) {
        self.connections_opened.fetch_add(1, Ordering::Relaxed);
        self.connections_active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.connections_active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Currently-open connections.
    pub fn active_connections(&self) -> u64 {
        self.connections_active.load(Ordering::Relaxed)
    }

    /// Record one served-request latency.
    pub fn note_latency(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        // Workers run requests under catch_unwind; recover the reservoir
        // rather than poisoning the whole metrics surface.
        let mut lat = self.lat.lock().unwrap_or_else(|p| p.into_inner());
        lat.total += 1;
        if lat.ring.len() < LAT_CAP {
            lat.ring.push(us);
        } else {
            let slot = lat.next;
            lat.ring[slot] = us;
        }
        lat.next = (lat.next + 1) % LAT_CAP;
    }

    /// p50/p95/p99 over the reservoir (zeros when nothing recorded).
    pub fn latency_summary(&self) -> LatencySummary {
        let lat = self.lat.lock().unwrap_or_else(|p| p.into_inner());
        let mut sorted = lat.ring.clone();
        let total = lat.total;
        drop(lat);
        if sorted.is_empty() {
            return LatencySummary {
                count: total,
                ..Default::default()
            };
        }
        sorted.sort_unstable();
        let pick = |q: f64| -> f64 {
            let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
            sorted[idx] as f64 / 1000.0
        };
        LatencySummary {
            count: total,
            p50_ms: pick(0.50),
            p95_ms: pick(0.95),
            p99_ms: pick(0.99),
        }
    }

    /// Snapshot every counter as a JSON object (the `stats` reply's
    /// `requests` / `latency` sections).
    pub fn snapshot_json(&self) -> Json {
        let lat = self.latency_summary();
        Json::obj(vec![
            (
                "requests",
                Json::obj(vec![
                    ("total", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
                    ("scored", Json::Num(self.scored.load(Ordering::Relaxed) as f64)),
                    (
                        "overloaded",
                        Json::Num(self.overloaded.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "deadline_exceeded",
                        Json::Num(self.deadline_exceeded.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "bad_requests",
                        Json::Num(self.bad_requests.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "bad_frames_parse",
                        Json::Num(self.bad_frames_parse.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "bad_frames_oversized",
                        Json::Num(self.bad_frames_oversized.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "internal_errors",
                        Json::Num(self.internal_errors.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "degraded",
                        Json::Num(self.degraded_responses.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "workers",
                Json::obj(vec![
                    ("panics", Json::Num(self.panics.load(Ordering::Relaxed) as f64)),
                    (
                        "respawns",
                        Json::Num(self.respawns.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "connections",
                Json::obj(vec![
                    (
                        "active",
                        Json::Num(self.connections_active.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "opened",
                        Json::Num(self.connections_opened.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "reaped_idle",
                        Json::Num(self.reaped_idle.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "reloads",
                Json::Num(self.reloads.load(Ordering::Relaxed) as f64),
            ),
            (
                "latency",
                Json::obj(vec![
                    ("count", Json::Num(lat.count as f64)),
                    ("p50_ms", Json::Num(lat.p50_ms)),
                    ("p95_ms", Json::Num(lat.p95_ms)),
                    ("p99_ms", Json::Num(lat.p99_ms)),
                ]),
            ),
            (
                "rows_scored",
                Json::Num(self.rows_scored.load(Ordering::Relaxed) as f64),
            ),
            ("uptime_s", Json::Num(self.uptime().as_secs_f64())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let m = Metrics::new();
        assert_eq!(m.latency_summary().count, 0);
        for i in 1..=100u64 {
            m.note_latency(Duration::from_millis(i));
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 100);
        assert!((s.p50_ms - 50.0).abs() <= 1.5, "p50 = {}", s.p50_ms);
        assert!((s.p95_ms - 95.0).abs() <= 1.5, "p95 = {}", s.p95_ms);
        assert!((s.p99_ms - 99.0).abs() <= 1.5, "p99 = {}", s.p99_ms);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
    }

    #[test]
    fn reservoir_is_bounded_but_count_is_total() {
        let m = Metrics::new();
        for _ in 0..(LAT_CAP + 100) {
            m.note_latency(Duration::from_micros(10));
        }
        let s = m.latency_summary();
        assert_eq!(s.count, (LAT_CAP + 100) as u64);
        assert_eq!(m.lat.lock().unwrap().ring.len(), LAT_CAP);
    }

    #[test]
    fn connection_gauge_tracks_open_and_close() {
        let m = Metrics::new();
        m.conn_opened();
        m.conn_opened();
        assert_eq!(m.active_connections(), 2);
        m.conn_closed();
        assert_eq!(m.active_connections(), 1);
        m.conn_closed();
        assert_eq!(m.active_connections(), 0);
        let j = m.snapshot_json();
        let conns = j.get("connections").unwrap();
        assert_eq!(conns.get("opened").unwrap().as_u64(), Some(2));
        assert_eq!(conns.get("active").unwrap().as_u64(), Some(0));
        assert_eq!(conns.get("reaped_idle").unwrap().as_u64(), Some(0));
        let workers = j.get("workers").unwrap();
        assert_eq!(workers.get("panics").unwrap().as_u64(), Some(0));
        assert_eq!(workers.get("respawns").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("reloads").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn snapshot_serializes_counters() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.scored.fetch_add(2, Ordering::Relaxed);
        m.overloaded.fetch_add(1, Ordering::Relaxed);
        m.rows_scored.fetch_add(512, Ordering::Relaxed);
        m.note_latency(Duration::from_millis(2));
        let j = m.snapshot_json();
        let req = j.get("requests").unwrap();
        assert_eq!(req.get("total").unwrap().as_u64(), Some(3));
        assert_eq!(req.get("overloaded").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("rows_scored").unwrap().as_u64(), Some(512));
        assert_eq!(j.get("latency").unwrap().get("count").unwrap().as_u64(), Some(1));
    }
}
