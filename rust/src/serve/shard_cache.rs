//! Warm shard cache with sequential prefetch.
//!
//! The streaming scorers re-read every shard once per pass (FIM,
//! self-influence, scores) and the serving daemon re-reads the whole store
//! per request. [`ShardCache`] keeps shard payloads resident *in their
//! on-disk encoded form* (`Vec<u8>`, per the store's
//! [`crate::store::PayloadDtype`]) under an LRU byte budget so repeat
//! passes hit memory — on quantized stores the same budget holds 2–4× more
//! rows than decoded f32 would — and an optional background prefetcher
//! overlaps the *next* shard's disk read with scoring of the current one.
//! Warm reads dequantize the requested rows straight into the caller's
//! buffer ([`crate::store::StoreReader::read_rows`]), never materializing
//! a decoded copy of the whole shard.
//!
//! Failure semantics: a shard that fails to load is **never** cached — the
//! typed [`StoreError`] propagates to the caller exactly as the uncached
//! path would, so [`crate::store::ReadGuard`] retry/quarantine behaviour is
//! unchanged with the cache attached.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::{Arc, Mutex};

use crate::store::{StoreError, StoreReader};

/// Point-in-time counters for a [`ShardCache`].
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub prefetch_loads: u64,
    pub evictions: u64,
    pub resident_shards: usize,
    pub resident_bytes: usize,
    pub budget_bytes: usize,
}

impl CacheStats {
    /// Fraction of lookups served from memory (0 when nothing was looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Inner {
    /// shard index → the shard's encoded payload bytes.
    map: HashMap<usize, Arc<Vec<u8>>>,
    /// LRU order, most recently used last.
    lru: Vec<usize>,
    bytes: usize,
}

/// LRU cache of encoded shard bytes with an optional sequential prefetcher.
///
/// Attach to a [`StoreReader`] with [`StoreReader::attach_cache`]; every
/// clone of that reader shares the cache, so concurrent streaming workers
/// and the serving daemon's scorers all warm the same pool.
pub struct ShardCache {
    budget: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    prefetch_loads: AtomicU64,
    evictions: AtomicU64,
    /// Hint channel into the prefetch thread; `None` until
    /// [`ShardCache::spawn_prefetcher`] runs. `Sender` is `!Sync`, hence
    /// the mutex.
    prefetch: Mutex<Option<Sender<usize>>>,
}

impl ShardCache {
    /// A cache that retains at most `budget_bytes` of encoded shard data.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget: budget_bytes,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                lru: Vec::new(),
                bytes: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            prefetch_loads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            prefetch: Mutex::new(None),
        }
    }

    /// Return shard `shard`'s encoded payload, loading it through
    /// `reader`'s fault-checked uncached path on a miss. Load failures are
    /// returned (not cached), so corruption surfaces on every attempt
    /// until the caller quarantines the shard.
    pub fn get_or_load(
        &self,
        reader: &StoreReader,
        shard: usize,
    ) -> std::result::Result<Arc<Vec<u8>>, StoreError> {
        if let Some(data) = self.lookup(shard) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(data);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Load outside the lock: concurrent misses on the same shard may
        // duplicate the read, but never block each other on disk I/O.
        let (_, data) = reader.read_shard_bytes_uncached(shard)?;
        let data = Arc::new(data);
        self.insert(shard, data.clone());
        Ok(data)
    }

    /// Whether shard `shard` is currently resident.
    pub fn contains(&self, shard: usize) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.map.contains_key(&shard)
    }

    /// Hint that shard `shard + 1` is likely next; the prefetch thread (if
    /// spawned) loads it in the background while the caller scores the
    /// current block.
    pub fn hint_next(&self, shard: usize, num_shards: usize) {
        let next = shard + 1;
        if next >= num_shards || self.contains(next) {
            return;
        }
        if let Some(tx) = self.prefetch.lock().unwrap().as_ref() {
            let _ = tx.send(next);
        }
    }

    /// Start a background prefetch thread reading hinted shards from the
    /// store at `dir` through its own uncached reader. The thread exits
    /// when the cache is dropped (the hint channel closes). Prefetch
    /// failures are silently skipped — the scoring read path will hit (and
    /// handle) the same error itself.
    pub fn spawn_prefetcher(self: &Arc<Self>, dir: PathBuf) {
        if let Ok(reader) = StoreReader::open(&dir) {
            self.spawn_prefetcher_with(reader);
        }
    }

    /// [`ShardCache::spawn_prefetcher`] with an explicit reader. The
    /// serving daemon passes a clone of its hot reader so the prefetch
    /// thread reads the same store epoch (and, under fault injection,
    /// sees the same fault plan instead of silently bypassing it).
    pub fn spawn_prefetcher_with(self: &Arc<Self>, reader: StoreReader) {
        let (tx, rx) = mpsc::channel::<usize>();
        *self.prefetch.lock().unwrap() = Some(tx);
        // Weak: the thread must not keep the cache (and thus the channel)
        // alive, or it would never observe the close.
        let cache = Arc::downgrade(self);
        std::thread::spawn(move || {
            while let Ok(shard) = rx.recv() {
                let Some(cache) = cache.upgrade() else { return };
                if cache.contains(shard) {
                    continue;
                }
                if let Ok((_, data)) = reader.read_shard_bytes_uncached(shard) {
                    cache.prefetch_loads.fetch_add(1, Ordering::Relaxed);
                    cache.insert(shard, Arc::new(data));
                }
            }
        });
    }

    /// Drop every resident shard (counters are kept).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.clear();
        inner.lru.clear();
        inner.bytes = 0;
    }

    /// Current counters and residency.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            prefetch_loads: self.prefetch_loads.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_shards: inner.map.len(),
            resident_bytes: inner.bytes,
            budget_bytes: self.budget,
        }
    }

    fn lookup(&self, shard: usize) -> Option<Arc<Vec<u8>>> {
        let mut inner = self.inner.lock().unwrap();
        let data = inner.map.get(&shard)?.clone();
        if let Some(pos) = inner.lru.iter().position(|&s| s == shard) {
            inner.lru.remove(pos);
        }
        inner.lru.push(shard);
        Some(data)
    }

    fn insert(&self, shard: usize, data: Arc<Vec<u8>>) {
        let bytes = data.len();
        if bytes > self.budget {
            return; // larger than the whole budget: serve it, don't cache it
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&shard) {
            return; // a concurrent miss or the prefetcher beat us to it
        }
        while inner.bytes + bytes > self.budget {
            if inner.lru.is_empty() {
                break;
            }
            let victim = inner.lru.remove(0);
            if let Some(old) = inner.map.remove(&victim) {
                inner.bytes -= old.len();
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.bytes += bytes;
        inner.map.insert(shard, data);
        inner.lru.push(shard);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreWriter;
    use std::path::PathBuf;

    fn tmp_store(tag: &str, n: usize, k: usize, shard_rows: usize) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("grass_shard_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut w = StoreWriter::create(&dir, k, "edge", 0, shard_rows).unwrap();
        for i in 0..n {
            let row: Vec<f32> = (0..k).map(|j| (i * k + j) as f32).collect();
            w.push(&row).unwrap();
        }
        w.finish().unwrap();
        dir
    }

    #[test]
    fn cache_hits_after_first_pass_and_matches_disk() {
        let dir = tmp_store("hits", 12, 4, 4);
        let mut reader = StoreReader::open(&dir).unwrap();
        let plain = reader.read_all().unwrap();
        let cache = Arc::new(ShardCache::new(1 << 20));
        reader.attach_cache(cache.clone());
        let warm1 = reader.read_all().unwrap();
        let warm2 = reader.read_all().unwrap();
        assert_eq!(plain, warm1);
        assert_eq!(plain, warm2);
        let stats = cache.stats();
        assert_eq!(stats.misses, 3, "one miss per shard on the first pass");
        assert_eq!(stats.hits, 3, "second pass fully warm");
        assert_eq!(stats.resident_shards, 3);
        assert!(stats.hit_rate() > 0.49);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_evicts_to_budget() {
        let dir = tmp_store("lru", 12, 4, 4);
        let mut reader = StoreReader::open(&dir).unwrap();
        // Budget fits exactly two 4×4 shards (4 rows × 4 cols × 4 bytes = 64).
        let cache = Arc::new(ShardCache::new(128));
        reader.attach_cache(cache.clone());
        reader.read_all().unwrap();
        let stats = cache.stats();
        assert_eq!(stats.resident_shards, 2);
        assert!(stats.evictions >= 1);
        assert!(stats.resident_bytes <= 128);
        // Shard 0 was evicted; the most recent two remain.
        assert!(!cache.contains(0));
        assert!(cache.contains(1) && cache.contains(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quantized_shards_stay_encoded_and_stretch_the_budget() {
        use crate::store::{PayloadDtype, StoreMeta};
        let dir = std::env::temp_dir()
            .join(format!("grass_shard_cache_f16_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let meta = StoreMeta {
            k: 4,
            n: 0,
            shard_rows: 4,
            method: "edge".into(),
            seed: 0,
            model: String::new(),
            input_dim: 0,
            layer_dims: vec![],
            density: 1.0,
            dtype: PayloadDtype::F16,
        };
        let mut w = crate::store::StoreWriter::create_described(&dir, meta).unwrap();
        for i in 0..12 {
            // Small integers are exactly representable in f16, so warm
            // reads must match disk bit-for-bit.
            let row: Vec<f32> = (0..4).map(|j| (i * 4 + j) as f32).collect();
            w.push(&row).unwrap();
        }
        w.finish().unwrap();
        let mut reader = StoreReader::open(&dir).unwrap();
        let plain = reader.read_all().unwrap();
        // 128 bytes held only two f32 shards (64 B each); the same budget
        // holds all three f16 shards (32 B each).
        let cache = Arc::new(ShardCache::new(128));
        reader.attach_cache(cache.clone());
        let warm1 = reader.read_all().unwrap();
        let warm2 = reader.read_all().unwrap();
        assert_eq!(plain, warm1);
        assert_eq!(plain, warm2);
        let stats = cache.stats();
        assert_eq!(stats.resident_shards, 3, "encoded f16 shards all fit");
        assert_eq!(stats.resident_bytes, 12 * 4 * 2, "resident bytes are encoded");
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.hits, 3, "second pass fully warm");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_is_not_cached_and_errors_every_time() {
        let dir = tmp_store("corrupt", 12, 4, 4);
        let shard1 = dir.join("shard_0001.bin");
        let len = std::fs::metadata(&shard1).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&shard1).unwrap();
        f.set_len(len - 8).unwrap();
        let mut reader = StoreReader::open(&dir).unwrap();
        let cache = Arc::new(ShardCache::new(1 << 20));
        reader.attach_cache(cache.clone());
        let mut buf = vec![0.0f32; 16];
        assert!(reader.read_rows(0, 4, &mut buf).is_ok());
        for _ in 0..2 {
            let err = reader.read_rows(4, 4, &mut buf).unwrap_err();
            assert!(err.to_string().contains("truncated or corrupted"), "{err}");
        }
        assert!(!cache.contains(1), "failed loads must not be cached");
        let stats = cache.stats();
        assert_eq!(stats.misses, 3, "each failed attempt is a fresh miss");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetcher_warms_the_next_shard() {
        let dir = tmp_store("prefetch", 16, 4, 4);
        let mut reader = StoreReader::open(&dir).unwrap();
        let cache = Arc::new(ShardCache::new(1 << 20));
        cache.spawn_prefetcher(dir.clone());
        reader.attach_cache(cache.clone());
        let mut buf = vec![0.0f32; 16];
        reader.read_rows(0, 4, &mut buf).unwrap();
        // The read of shard 0 hints shard 1; wait for the background load.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !cache.contains(1) && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(cache.contains(1), "prefetcher never loaded the hinted shard");
        reader.read_rows(4, 4, &mut buf).unwrap();
        assert_eq!(buf[0], 16.0);
        let stats = cache.stats();
        assert!(stats.prefetch_loads >= 1);
        assert!(stats.hits >= 1, "the prefetched shard should hit");
        std::fs::remove_dir_all(&dir).ok();
    }
}
