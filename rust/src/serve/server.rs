//! The serving daemon: hot state built once, then a bounded worker pool
//! scoring requests for the lifetime of the process.
//!
//! Startup opens the store a single time (manifest-verified), optionally
//! attaches a prefetching [`ShardCache`], rebuilds the
//! [`CompressorBank`], loads + validates the persisted
//! [`PrecondArtifact`](crate::attrib::PrecondArtifact), and runs each
//! configured scorer's `cache_stream` ingest (FIM + self-influence passes)
//! exactly once. Every subsequent request reuses that state — observable
//! via the `stats` request: `store.opens` stays 1 and per-engine
//! `fim_rows` never grows while `requests.scored` does.

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, ensure, Context};

use crate::attrib::{from_spec, AttributionSpec, Attributor, PrecondArtifact, PrecondSpec, StreamOpts, DEFAULT_MEM_BUDGET};
use crate::coordinator::CompressorBank;
use crate::data::queries::{compress_raw_queries, synth_queries};
use crate::data::synthgrad::SYNTH_MODEL;
use crate::serve::admission::{Admission, Deadline, Ticket};
use crate::serve::metrics::Metrics;
use crate::serve::proto::{
    CoverageInfo, ErrorKind, QueryPayload, Response, ScoreRequest, ScoreResponse,
};
use crate::serve::shard_cache::ShardCache;
use crate::store::{RetryPolicy, StoreMeta, StoreReader};
use crate::util::json::Json;
use crate::Result;

/// Everything `grass serve` configures about a daemon instance.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Store directory to serve.
    pub store: PathBuf,
    /// Bind address (`host:port`; port 0 auto-assigns — the bound address
    /// is reported by [`ServerHandle::addr`]).
    pub addr: String,
    /// Scorers kept hot (each pays its ingest passes once at startup).
    pub scorers: Vec<String>,
    /// Scoring worker threads.
    pub workers: usize,
    /// Admission bound: queued + running score requests; 0 sheds all.
    pub max_in_flight: usize,
    /// Default per-request latency budget (ms); 0 = no deadline.
    pub deadline_ms: u64,
    /// Streaming byte budget per scoring pass.
    pub mem_budget: usize,
    /// Warm shard-cache byte budget; 0 disables the cache.
    pub cache_bytes: usize,
    /// Quarantine corrupt shards and serve degraded coverage instead of
    /// failing requests.
    pub skip_corrupt: bool,
    /// Transient-read retry policy.
    pub retries: usize,
    pub retry_backoff_ms: u64,
    /// Run a full checksum scan before serving (refuse to start on
    /// corruption unless `skip_corrupt` is set).
    pub verify: bool,
    /// Consume a persisted `precond.bin` artifact when present + valid.
    pub use_artifact: bool,
    /// FIM damping λ for the preconditioned scorers.
    pub damping: f64,
    /// Explicit preconditioner spec; `None` = each scorer's default.
    pub precond: Option<String>,
    /// Suppress stdout chatter (tests / benches).
    pub quiet: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            store: PathBuf::from("grass_store"),
            addr: "127.0.0.1:0".to_string(),
            scorers: vec!["if".to_string(), "graddot".to_string()],
            workers: 2,
            max_in_flight: 32,
            deadline_ms: 10_000,
            mem_budget: DEFAULT_MEM_BUDGET,
            cache_bytes: 256 << 20,
            skip_corrupt: false,
            retries: 2,
            retry_backoff_ms: 50,
            verify: false,
            use_artifact: true,
            damping: 1e-3,
            precond: None,
            quiet: false,
        }
    }
}

/// Canonical scorer id (the registry aliases collapsed), so config keys
/// and request keys always meet.
pub(crate) fn canon_scorer(s: &str) -> &str {
    match s {
        "influence" => "if",
        "dot" => "graddot",
        "bw" => "blockwise",
        other => other,
    }
}

/// One resident scorer: ingested once at startup, shared by all workers.
pub(crate) struct Engine {
    pub attributor: Box<dyn Attributor>,
    pub fim_rows: usize,
    pub describe: String,
}

/// A queued scoring job: request + admission ticket + reply channel.
pub(crate) struct Job {
    pub req: ScoreRequest,
    pub deadline: Deadline,
    pub ticket: Ticket,
    pub reply: Sender<Response>,
}

/// Shared daemon state (hot stores, engines, metrics, shutdown plumbing).
pub(crate) struct ServerState {
    pub cfg: ServeConfig,
    pub meta: StoreMeta,
    pub bank: CompressorBank,
    pub engines: BTreeMap<String, Engine>,
    pub admission: Arc<Admission>,
    pub metrics: Metrics,
    pub cache: Option<Arc<ShardCache>>,
    pub artifact_loaded: bool,
    /// Store opens over the daemon's lifetime — 1 by construction; the
    /// `stats` request exposes it so hot-state reuse is testable.
    pub store_opens: AtomicU64,
    pub jobs: Mutex<Option<Sender<Job>>>,
    pub shutdown: AtomicBool,
    pub addr: SocketAddr,
}

impl ServerState {
    /// Flip the shutdown flag and poke the accept loop awake.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
    }

    /// The full `stats`-request payload: metrics counters + hot-state
    /// evidence (store opens, per-engine fim rows, cache hit rate).
    pub fn stats_json(&self) -> Json {
        let mut map = match self.metrics.snapshot_json() {
            Json::Obj(m) => m,
            _ => unreachable!("metrics snapshot is an object"),
        };
        map.insert(
            "store".to_string(),
            Json::obj(vec![
                ("dir", Json::Str(self.cfg.store.display().to_string())),
                ("n", Json::Num(self.meta.n as f64)),
                ("k", Json::Num(self.meta.k as f64)),
                ("method", Json::Str(self.meta.method.clone())),
                ("dtype", Json::Str(self.meta.dtype.as_str().to_string())),
                ("bytes_per_row", Json::Num(self.meta.row_bytes() as f64)),
                (
                    "shards",
                    Json::Num(self.meta.n.div_ceil(self.meta.shard_rows.max(1)) as f64),
                ),
                (
                    "opens",
                    Json::Num(self.store_opens.load(Ordering::Relaxed) as f64),
                ),
            ]),
        );
        let engines = self
            .engines
            .iter()
            .map(|(name, e)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("fim_rows", Json::Num(e.fim_rows as f64)),
                        ("precond", Json::Str(e.describe.clone())),
                    ]),
                )
            })
            .collect();
        map.insert("engines".to_string(), Json::Obj(engines));
        map.insert("artifact_loaded".to_string(), Json::Bool(self.artifact_loaded));
        map.insert(
            "admission".to_string(),
            Json::obj(vec![
                ("queue_depth", Json::Num(self.admission.depth() as f64)),
                (
                    "max_in_flight",
                    Json::Num(self.admission.max_in_flight() as f64),
                ),
                ("workers", Json::Num(self.cfg.workers as f64)),
            ]),
        );
        let cache = match &self.cache {
            Some(c) => {
                let s = c.stats();
                Json::obj(vec![
                    ("hits", Json::Num(s.hits as f64)),
                    ("misses", Json::Num(s.misses as f64)),
                    ("hit_rate", Json::Num(s.hit_rate())),
                    ("prefetch_loads", Json::Num(s.prefetch_loads as f64)),
                    ("evictions", Json::Num(s.evictions as f64)),
                    ("resident_shards", Json::Num(s.resident_shards as f64)),
                    ("resident_bytes", Json::Num(s.resident_bytes as f64)),
                    ("budget_bytes", Json::Num(s.budget_bytes as f64)),
                ])
            }
            None => Json::Null,
        };
        map.insert("shard_cache".to_string(), cache);
        Json::Obj(map)
    }
}

/// Build the daemon's hot state: one store open, one bank rebuild, one
/// artifact load, one ingest per scorer.
fn build_state(cfg: ServeConfig) -> Result<ServerState> {
    ensure!(!cfg.scorers.is_empty(), "serve needs at least one --scorer");
    let mut reader = StoreReader::open(&cfg.store)?;
    if cfg.verify {
        let report = reader.verify_checksums()?;
        if !report.all_ok() {
            let bad: Vec<usize> = report
                .shards
                .iter()
                .filter(|(_, s)| !s.is_ok())
                .map(|(i, _)| *i)
                .collect();
            ensure!(
                cfg.skip_corrupt,
                "store at {} failed verification (bad shards: {bad:?}); refusing to serve — \
                 pass --skip-corrupt to serve degraded",
                cfg.store.display()
            );
            if !cfg.quiet {
                eprintln!(
                    "warning: serving degraded — verification flagged shards {bad:?} at {}",
                    cfg.store.display()
                );
            }
        }
    }
    let cache = if cfg.cache_bytes > 0 {
        let cache = Arc::new(ShardCache::new(cfg.cache_bytes));
        cache.spawn_prefetcher(cfg.store.clone());
        reader.attach_cache(cache.clone());
        Some(cache)
    } else {
        None
    };
    let shapes = reader.meta.shapes();
    ensure!(
        shapes.p > 0 || !shapes.layers.is_empty(),
        "store at {} records no gradient geometry (pre-redesign cache?); re-run `grass cache`",
        cfg.store.display()
    );
    let spec = reader.meta.spec()?;
    let seed = reader.meta.seed;
    let bank = spec.build_bank(&shapes, seed)?;
    ensure!(
        bank.output_dim() == reader.meta.k,
        "rebuilt bank emits {} columns but the store has k = {}",
        bank.output_dim(),
        reader.meta.k
    );
    let model = reader.meta.model.as_str();
    ensure!(
        model == SYNTH_MODEL || model.is_empty(),
        "serving store model '{model}' needs the PJRT runtime per query; only synthetic-model \
         stores are servable today"
    );

    let artifact = if cfg.use_artifact {
        match PrecondArtifact::load_if_present(&cfg.store)? {
            Some(a) => {
                a.validate_store(&reader.meta)?;
                Some(Arc::new(a))
            }
            None => None,
        }
    } else {
        None
    };
    let artifact_loaded = artifact.is_some();

    let base_opts = StreamOpts {
        mem_budget: cfg.mem_budget,
        workers: cfg.workers.max(1),
        retry: RetryPolicy {
            retries: cfg.retries,
            backoff: Duration::from_millis(cfg.retry_backoff_ms),
            seed,
        },
        skip_corrupt: cfg.skip_corrupt,
        ..StreamOpts::default()
    };

    let mut engines = BTreeMap::new();
    for name in &cfg.scorers {
        let scorer = canon_scorer(name).to_string();
        if engines.contains_key(&scorer) {
            continue;
        }
        let pspec = match &cfg.precond {
            Some(s) => PrecondSpec::parse_with(s, cfg.damping)?,
            None => PrecondSpec::default_for_scorer(&scorer, cfg.damping),
        };
        let mut opts = base_opts.clone();
        if pspec.needs_fim() {
            opts.artifact = artifact.clone();
        }
        let mut aspec = AttributionSpec::new(&scorer, spec.clone(), seed);
        aspec.damping = cfg.damping;
        aspec.layout = bank.layer_dims();
        aspec.precond = Some(pspec);
        let mut attributor = from_spec(&aspec)
            .with_context(|| format!("building serve engine for scorer '{scorer}'"))?;
        attributor
            .cache_stream(&reader, &opts)
            .with_context(|| format!("ingesting store for scorer '{scorer}'"))?;
        let pstats = attributor.precond_stats();
        engines.insert(
            scorer,
            Engine {
                attributor,
                fim_rows: pstats.fim_rows,
                describe: pstats.describe,
            },
        );
    }

    Ok(ServerState {
        admission: Arc::new(Admission::new(cfg.max_in_flight)),
        meta: reader.meta.clone(),
        bank,
        engines,
        metrics: Metrics::new(),
        cache,
        artifact_loaded,
        store_opens: AtomicU64::new(1),
        jobs: Mutex::new(None),
        shutdown: AtomicBool::new(false),
        addr: "127.0.0.1:0".parse().expect("literal addr"),
        cfg,
    })
}

/// Score one admitted job (already past admission + deadline checks).
fn score_request(state: &ServerState, req: &ScoreRequest, deadline: &Deadline) -> Response {
    let id = req.id;
    let scorer = canon_scorer(&req.scorer).to_string();
    let Some(engine) = state.engines.get(&scorer) else {
        let available: Vec<&str> = state.engines.keys().map(|s| s.as_str()).collect();
        return Response::Error {
            id,
            kind: ErrorKind::BadRequest,
            message: format!("scorer '{}' is not loaded (serving: {available:?})", req.scorer),
        };
    };
    let m = req.queries.m();
    let k = state.meta.k;
    let (queries, classes) = match &req.queries {
        QueryPayload::Synth { m } => match synth_queries(&state.meta, &state.bank, *m) {
            Ok((q, c)) => (q, Some(c)),
            Err(e) => {
                return Response::Error {
                    id,
                    kind: ErrorKind::Internal,
                    message: format!("synthesising queries: {e:#}"),
                }
            }
        },
        QueryPayload::Raw { m, rows } => match compress_raw_queries(&state.bank, rows, *m) {
            Ok(q) => (q, None),
            Err(e) => {
                return Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!("raw queries rejected: {e:#}"),
                }
            }
        },
        QueryPayload::Compressed { m, rows } => {
            if rows.len() != m * k {
                return Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!(
                        "compressed queries hold {} values but m = {m} × k = {k} requires {}",
                        rows.len(),
                        m * k
                    ),
                };
            }
            (rows.clone(), None)
        }
    };
    let scores = match engine.attributor.attribute(&queries, m) {
        Ok(s) => s,
        Err(e) => {
            state.metrics.internal_errors.fetch_add(1, Ordering::Relaxed);
            return Response::Error {
                id,
                kind: ErrorKind::Internal,
                message: format!("scoring failed: {e:#}"),
            };
        }
    };
    let top: Vec<Vec<(usize, f32)>> = (0..m).map(|q| scores.top_k(q, req.top_k)).collect();
    let self_influence = if req.self_influence {
        match engine.attributor.self_influence() {
            Ok(si) => Some(si),
            Err(e) => {
                state.metrics.internal_errors.fetch_add(1, Ordering::Relaxed);
                return Response::Error {
                    id,
                    kind: ErrorKind::Internal,
                    message: format!("self-influence failed: {e:#}"),
                };
            }
        }
    } else {
        None
    };
    let coverage = match engine.attributor.coverage() {
        Some(c) => CoverageInfo {
            rows_total: c.rows_total,
            rows_scored: c.rows_scored,
            quarantined: c.quarantined,
            retries_attempted: c.retries_attempted,
        },
        None => CoverageInfo {
            rows_total: state.meta.n,
            rows_scored: state.meta.n,
            quarantined: vec![],
            retries_attempted: 0,
        },
    };
    state.metrics.scored.fetch_add(1, Ordering::Relaxed);
    state
        .metrics
        .rows_scored
        .fetch_add(coverage.rows_scored as u64, Ordering::Relaxed);
    if coverage.is_degraded() {
        state
            .metrics
            .degraded_responses
            .fetch_add(1, Ordering::Relaxed);
    }
    Response::Scores(Box::new(ScoreResponse {
        id,
        scorer,
        m,
        n: scores.n,
        top,
        scores: req.include_scores.then(|| {
            let mut flat = Vec::with_capacity(m * scores.n);
            for q in 0..m {
                flat.extend_from_slice(scores.row(q));
            }
            flat
        }),
        self_influence,
        classes,
        coverage,
        elapsed_ms: deadline.elapsed().as_secs_f64() * 1e3,
    }))
}

/// One worker: drain jobs until the channel closes.
fn worker_loop(state: Arc<ServerState>, rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(Job {
            req,
            deadline,
            ticket,
            reply,
        }) = job
        else {
            return; // sender dropped: shutdown drain finished
        };
        let resp = if deadline.expired() {
            state
                .metrics
                .deadline_exceeded
                .fetch_add(1, Ordering::Relaxed);
            Response::Error {
                id: req.id,
                kind: ErrorKind::DeadlineExceeded,
                message: format!(
                    "request waited {:.1} ms, past its deadline",
                    deadline.elapsed().as_secs_f64() * 1e3
                ),
            }
        } else {
            let r = score_request(&state, &req, &deadline);
            if matches!(r, Response::Scores(_)) {
                state.metrics.note_latency(deadline.elapsed());
            }
            r
        };
        drop(ticket); // free the admission slot before the reply blocks
        let _ = reply.send(resp);
    }
}

/// A running daemon: bound address + join handle.
pub struct ServerHandle {
    addr: SocketAddr,
    accept: JoinHandle<()>,
}

impl ServerHandle {
    /// The actually bound address (resolves `:0` auto-assignment).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the daemon shuts down (via a `shutdown` request).
    pub fn join(self) -> Result<()> {
        self.accept
            .join()
            .map_err(|_| anyhow!("serve accept thread panicked"))
    }
}

/// Build hot state, bind, and start serving in background threads.
/// Returns once the daemon is accepting connections.
pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle> {
    let mut state = build_state(cfg)?;
    let listener = TcpListener::bind(&state.cfg.addr)
        .with_context(|| format!("binding {}", state.cfg.addr))?;
    let addr = listener.local_addr()?;
    state.addr = addr;
    let state = Arc::new(state);

    let (tx, rx) = mpsc::channel::<Job>();
    *state.jobs.lock().unwrap() = Some(tx);
    let rx = Arc::new(Mutex::new(rx));
    let workers: Vec<JoinHandle<()>> = (0..state.cfg.workers.max(1))
        .map(|_| {
            let state = state.clone();
            let rx = rx.clone();
            std::thread::spawn(move || worker_loop(state, rx))
        })
        .collect();

    let accept_state = state.clone();
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if accept_state.shutdown.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let conn_state = accept_state.clone();
            std::thread::spawn(move || crate::serve::session::handle_conn(stream, conn_state));
        }
        // Drain: close the job channel, let workers finish queued work.
        drop(accept_state.jobs.lock().unwrap().take());
        for w in workers {
            let _ = w.join();
        }
        if !accept_state.cfg.quiet {
            println!("serve: graceful shutdown — final metrics:");
            println!("{}", accept_state.stats_json().to_string_pretty());
        }
    });
    Ok(ServerHandle { addr, accept })
}

/// `grass serve` entry point: spawn, announce, and block until shutdown.
pub fn run(cfg: ServeConfig) -> Result<()> {
    let quiet = cfg.quiet;
    let store = cfg.store.clone();
    let scorers = cfg.scorers.clone();
    let handle = spawn(cfg)?;
    if !quiet {
        println!(
            "serve: listening on {} (store {}, scorers {scorers:?}) — send a shutdown \
             request or `grass query --addr {} --shutdown` to stop",
            handle.addr(),
            store.display(),
            handle.addr()
        );
    }
    handle.join()
}
