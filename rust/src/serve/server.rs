//! The serving daemon: epoch-versioned hot state, a supervised worker
//! pool, and a single draining shutdown sequence.
//!
//! Startup builds one [`HotState`] (store opened once, engines ingested
//! once) and pins it behind an `RwLock<Arc<_>>`; every request clones the
//! `Arc`, so a `reload` request can build a replacement epoch in the
//! background and swap it in without failing anything in flight.
//! Observable via the `stats` request: `store.opens` counts exactly one
//! open per epoch, per-engine `fim_rows` never grows while
//! `requests.scored` does, and `epoch` ticks only on reload.
//!
//! Resilience model:
//! - workers run each job under `catch_unwind`; a panicking scorer
//!   produces a typed `internal` reply and the supervisor (the accept
//!   loop) respawns the dead worker (`workers.panics` / `workers.respawns`
//!   in stats);
//! - SIGTERM/SIGINT (CLI path only) and the protocol `shutdown` request
//!   are two doors into the same drain: stop accepting, finish queued
//!   work within `--drain-ms`, join workers, dump final metrics;
//! - shards that keep failing reads trip a circuit breaker inside the
//!   shared [`ReadLog`](crate::store::ReadLog) and are quarantined for
//!   the rest of the epoch (a reload clears the breaker).

use std::net::{SocketAddr, TcpListener};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::attrib::DEFAULT_MEM_BUDGET;
use crate::data::queries::{compress_raw_queries, synth_queries};
use crate::serve::admission::{Admission, Deadline, Ticket};
use crate::serve::hot::{canon_scorer, HotState};
use crate::serve::metrics::Metrics;
use crate::serve::proto::{
    CoverageInfo, ErrorKind, QueryPayload, Response, ScoreRequest, ScoreResponse,
};
use crate::serve::signal;
use crate::util::json::Json;
use crate::Result;

/// Supervisor / accept-loop poll interval.
const ACCEPT_TICK: Duration = Duration::from_millis(10);

/// Everything `grass serve` configures about a daemon instance.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Store directory to serve.
    pub store: PathBuf,
    /// Bind address (`host:port`; port 0 auto-assigns — the bound address
    /// is reported by [`ServerHandle::addr`]).
    pub addr: String,
    /// Scorers kept hot (each pays its ingest passes once per epoch).
    pub scorers: Vec<String>,
    /// Scoring worker threads.
    pub workers: usize,
    /// Admission bound: queued + running score requests; 0 sheds all.
    pub max_in_flight: usize,
    /// Default per-request latency budget (ms); 0 = no deadline.
    pub deadline_ms: u64,
    /// Streaming byte budget per scoring pass.
    pub mem_budget: usize,
    /// Warm shard-cache byte budget; 0 disables the cache.
    pub cache_bytes: usize,
    /// Quarantine corrupt shards and serve degraded coverage instead of
    /// failing requests.
    pub skip_corrupt: bool,
    /// Transient-read retry policy.
    pub retries: usize,
    pub retry_backoff_ms: u64,
    /// Run a full checksum scan before serving (refuse to start on
    /// corruption unless `skip_corrupt` is set).
    pub verify: bool,
    /// Consume a persisted `precond.bin` artifact when present + valid.
    pub use_artifact: bool,
    /// FIM damping λ for the preconditioned scorers.
    pub damping: f64,
    /// Explicit preconditioner spec; `None` = each scorer's default.
    pub precond: Option<String>,
    /// Shutdown drain budget (ms): queued work and open connections get
    /// this long to finish before the drain is forced.
    pub drain_ms: u64,
    /// Idle-connection reap threshold (ms); 0 disables the reaper.
    pub idle_ms: u64,
    /// Circuit-breaker threshold: failed reads of one shard before it is
    /// quarantined for the epoch; 0 disarms the breaker.
    pub breaker: usize,
    /// Suppress stdout chatter (tests / benches).
    pub quiet: bool,
    /// Scripted store faults injected into the epoch's reader (chaos
    /// tests only; release builds have no injection path).
    #[cfg(any(test, feature = "fault-injection"))]
    pub faults: Option<Arc<crate::store::FaultPlan>>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            store: PathBuf::from("grass_store"),
            addr: "127.0.0.1:0".to_string(),
            scorers: vec!["if".to_string(), "graddot".to_string()],
            workers: 2,
            max_in_flight: 32,
            deadline_ms: 10_000,
            mem_budget: DEFAULT_MEM_BUDGET,
            cache_bytes: 256 << 20,
            skip_corrupt: false,
            retries: 2,
            retry_backoff_ms: 50,
            verify: false,
            use_artifact: true,
            damping: 1e-3,
            precond: None,
            drain_ms: 5_000,
            idle_ms: 30_000,
            breaker: 3,
            quiet: false,
            #[cfg(any(test, feature = "fault-injection"))]
            faults: None,
        }
    }
}

/// A queued scoring job: request + admission ticket + reply channel.
pub(crate) struct Job {
    pub req: ScoreRequest,
    pub deadline: Deadline,
    pub ticket: Ticket,
    pub reply: Sender<Response>,
}

/// Shared daemon state: the swappable hot epoch plus everything that
/// outlives reloads (admission, metrics, shutdown plumbing).
pub(crate) struct ServerState {
    pub cfg: ServeConfig,
    /// Current epoch; workers clone the `Arc` per job so a swap never
    /// yanks state out from under an in-flight request.
    pub hot: RwLock<Arc<HotState>>,
    pub admission: Arc<Admission>,
    pub metrics: Metrics,
    /// Store opens over the daemon's lifetime — exactly one per epoch;
    /// the `stats` request exposes it so hot-state reuse is testable.
    pub store_opens: AtomicU64,
    /// Single-flight guard for reloads.
    pub reloading: AtomicBool,
    pub jobs: Mutex<Option<Sender<Job>>>,
    pub shutdown: AtomicBool,
    /// What triggered the drain ("SIGTERM", "shutdown request", …).
    pub drain_reason: Mutex<Option<String>>,
    /// Final drain report, filled once the drain sequence finishes.
    pub drain_report: Mutex<Option<Json>>,
    pub addr: SocketAddr,
}

fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl ServerState {
    /// The current hot epoch (cloned `Arc`: safe across a concurrent swap).
    pub fn hot(&self) -> Arc<HotState> {
        self.hot
            .read()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    /// Enter the drain sequence: record the trigger (first one wins) and
    /// flip the flag the accept loop and sessions poll.
    pub fn begin_shutdown(&self, reason: &str) {
        let mut r = lock_unpoisoned(&self.drain_reason);
        if r.is_none() {
            *r = Some(reason.to_string());
        }
        drop(r);
        self.shutdown.store(true, Ordering::Release);
    }

    /// Serve a `reload` request: rebuild hot state (same dir, or `store`
    /// when given) and atomically swap epochs. Single-flight; refusals
    /// (spec mismatch, unreadable store) keep the current epoch serving.
    pub fn try_reload(&self, id: u64, store: Option<&str>) -> Response {
        if self.reloading.swap(true, Ordering::AcqRel) {
            return Response::Error {
                id,
                kind: ErrorKind::Overloaded,
                message: "a reload is already in progress".to_string(),
            };
        }
        let result = self.do_reload(store);
        self.reloading.store(false, Ordering::Release);
        match result {
            Ok((epoch, dir)) => Response::Reloaded {
                id,
                epoch,
                store: dir,
            },
            Err(e) => Response::Error {
                id,
                kind: ErrorKind::BadRequest,
                message: format!("reload refused: {e:#}"),
            },
        }
    }

    fn do_reload(&self, store: Option<&str>) -> Result<(u64, String)> {
        let cur = self.hot();
        let dir = match store {
            Some(s) => PathBuf::from(s),
            None => cur.dir.clone(),
        };
        // Build the whole replacement epoch before touching the lock:
        // in-flight and new requests keep scoring on the old epoch for
        // the full (potentially long) ingest.
        let next = HotState::build(&self.cfg, &dir, cur.epoch + 1, Some(&cur.meta))
            .with_context(|| format!("rebuilding hot state from {}", dir.display()))?;
        self.store_opens.fetch_add(1, Ordering::Relaxed);
        let epoch = next.epoch;
        *self.hot.write().unwrap_or_else(|p| p.into_inner()) = Arc::new(next);
        self.metrics.reloads.fetch_add(1, Ordering::Relaxed);
        if !self.cfg.quiet {
            println!(
                "serve: hot reload complete — epoch {epoch} now serving {}",
                dir.display()
            );
        }
        Ok((epoch, dir.display().to_string()))
    }

    /// The full `stats`-request payload: metrics counters + hot-state
    /// evidence (store opens, epoch, per-engine fim rows, cache hit rate,
    /// breaker state, drain report).
    pub fn stats_json(&self) -> Json {
        let hot = self.hot();
        let mut map = match self.metrics.snapshot_json() {
            Json::Obj(m) => m,
            _ => unreachable!("metrics snapshot is an object"),
        };
        map.insert("epoch".to_string(), Json::Num(hot.epoch as f64));
        map.insert(
            "simd_isa".to_string(),
            Json::Str(crate::linalg::simd::active_isa().to_string()),
        );
        map.insert(
            "store".to_string(),
            Json::obj(vec![
                ("dir", Json::Str(hot.dir.display().to_string())),
                ("n", Json::Num(hot.meta.n as f64)),
                ("k", Json::Num(hot.meta.k as f64)),
                ("method", Json::Str(hot.meta.method.clone())),
                ("dtype", Json::Str(hot.meta.dtype.as_str().to_string())),
                ("bytes_per_row", Json::Num(hot.meta.row_bytes() as f64)),
                (
                    "shards",
                    Json::Num(hot.meta.n.div_ceil(hot.meta.shard_rows.max(1)) as f64),
                ),
                (
                    "opens",
                    Json::Num(self.store_opens.load(Ordering::Relaxed) as f64),
                ),
            ]),
        );
        let engines = hot
            .engines
            .iter()
            .map(|(name, e)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("fim_rows", Json::Num(e.fim_rows as f64)),
                        ("precond", Json::Str(e.describe.clone())),
                    ]),
                )
            })
            .collect();
        map.insert("engines".to_string(), Json::Obj(engines));
        map.insert("artifact_loaded".to_string(), Json::Bool(hot.artifact_loaded));
        map.insert(
            "admission".to_string(),
            Json::obj(vec![
                ("queue_depth", Json::Num(self.admission.depth() as f64)),
                (
                    "max_in_flight",
                    Json::Num(self.admission.max_in_flight() as f64),
                ),
                ("workers", Json::Num(self.cfg.workers as f64)),
            ]),
        );
        let log = &hot.read_log;
        map.insert(
            "breaker".to_string(),
            Json::obj(vec![
                ("threshold", Json::Num(log.breaker_threshold() as f64)),
                ("trips", Json::Num(log.breaker_trips() as f64)),
                (
                    "quarantined",
                    Json::Arr(
                        log.quarantined()
                            .into_iter()
                            .map(|s| Json::Num(s as f64))
                            .collect(),
                    ),
                ),
                (
                    "failed_reads",
                    Json::Num(
                        log.failure_counts().iter().map(|(_, c)| *c).sum::<u64>() as f64,
                    ),
                ),
            ]),
        );
        let cache = match &hot.cache {
            Some(c) => {
                let s = c.stats();
                Json::obj(vec![
                    ("hits", Json::Num(s.hits as f64)),
                    ("misses", Json::Num(s.misses as f64)),
                    ("hit_rate", Json::Num(s.hit_rate())),
                    ("prefetch_loads", Json::Num(s.prefetch_loads as f64)),
                    ("evictions", Json::Num(s.evictions as f64)),
                    ("resident_shards", Json::Num(s.resident_shards as f64)),
                    ("resident_bytes", Json::Num(s.resident_bytes as f64)),
                    ("budget_bytes", Json::Num(s.budget_bytes as f64)),
                ])
            }
            None => Json::Null,
        };
        map.insert("shard_cache".to_string(), cache);
        map.insert(
            "drain".to_string(),
            lock_unpoisoned(&self.drain_report)
                .clone()
                .unwrap_or(Json::Null),
        );
        Json::Obj(map)
    }
}

/// Score one admitted job (already past admission + deadline checks)
/// against the epoch it was admitted under.
fn score_request(
    state: &ServerState,
    hot: &HotState,
    req: &ScoreRequest,
    deadline: &Deadline,
) -> Response {
    let id = req.id;
    #[cfg(any(test, feature = "fault-injection"))]
    if req.scorer == "__panic__" {
        panic!("injected worker panic (scorer '__panic__')");
    }
    let scorer = canon_scorer(&req.scorer).to_string();
    let Some(engine) = hot.engines.get(&scorer) else {
        let available: Vec<&str> = hot.engines.keys().map(|s| s.as_str()).collect();
        return Response::Error {
            id,
            kind: ErrorKind::BadRequest,
            message: format!("scorer '{}' is not loaded (serving: {available:?})", req.scorer),
        };
    };
    let m = req.queries.m();
    let k = hot.meta.k;
    let (queries, classes) = match &req.queries {
        QueryPayload::Synth { m } => match synth_queries(&hot.meta, &hot.bank, *m) {
            Ok((q, c)) => (q, Some(c)),
            Err(e) => {
                return Response::Error {
                    id,
                    kind: ErrorKind::Internal,
                    message: format!("synthesising queries: {e:#}"),
                }
            }
        },
        QueryPayload::Raw { m, rows } => match compress_raw_queries(&hot.bank, rows, *m) {
            Ok(q) => (q, None),
            Err(e) => {
                return Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!("raw queries rejected: {e:#}"),
                }
            }
        },
        QueryPayload::Compressed { m, rows } => {
            if rows.len() != m * k {
                return Response::Error {
                    id,
                    kind: ErrorKind::BadRequest,
                    message: format!(
                        "compressed queries hold {} values but m = {m} × k = {k} requires {}",
                        rows.len(),
                        m * k
                    ),
                };
            }
            (rows.clone(), None)
        }
    };
    let scores = match engine.attributor.attribute(&queries, m) {
        Ok(s) => s,
        Err(e) => {
            state.metrics.internal_errors.fetch_add(1, Ordering::Relaxed);
            return Response::Error {
                id,
                kind: ErrorKind::Internal,
                message: format!("scoring failed: {e:#}"),
            };
        }
    };
    let top: Vec<Vec<(usize, f32)>> = (0..m).map(|q| scores.top_k(q, req.top_k)).collect();
    let self_influence = if req.self_influence {
        match engine.attributor.self_influence() {
            Ok(si) => Some(si),
            Err(e) => {
                state.metrics.internal_errors.fetch_add(1, Ordering::Relaxed);
                return Response::Error {
                    id,
                    kind: ErrorKind::Internal,
                    message: format!("self-influence failed: {e:#}"),
                };
            }
        }
    } else {
        None
    };
    let coverage = match engine.attributor.coverage() {
        Some(c) => CoverageInfo {
            rows_total: c.rows_total,
            rows_scored: c.rows_scored,
            quarantined: c.quarantined,
            retries_attempted: c.retries_attempted,
        },
        None => CoverageInfo {
            rows_total: hot.meta.n,
            rows_scored: hot.meta.n,
            quarantined: vec![],
            retries_attempted: 0,
        },
    };
    state.metrics.scored.fetch_add(1, Ordering::Relaxed);
    state
        .metrics
        .rows_scored
        .fetch_add(coverage.rows_scored as u64, Ordering::Relaxed);
    if coverage.is_degraded() {
        state
            .metrics
            .degraded_responses
            .fetch_add(1, Ordering::Relaxed);
    }
    Response::Scores(Box::new(ScoreResponse {
        id,
        scorer,
        m,
        n: scores.n,
        top,
        scores: req.include_scores.then(|| {
            let mut flat = Vec::with_capacity(m * scores.n);
            for q in 0..m {
                flat.extend_from_slice(scores.row(q));
            }
            flat
        }),
        self_influence,
        classes,
        coverage,
        epoch: hot.epoch,
        elapsed_ms: deadline.elapsed().as_secs_f64() * 1e3,
    }))
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// One worker: drain jobs until the channel closes. Each job runs under
/// `catch_unwind`, so a panicking scorer answers its client with a typed
/// `internal` error instead of hanging the session; the worker then exits
/// and the supervisor respawns it.
fn worker_loop(state: Arc<ServerState>, rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = lock_unpoisoned(&rx);
            guard.recv()
        };
        let Ok(Job {
            req,
            deadline,
            ticket,
            reply,
        }) = job
        else {
            return; // sender dropped: shutdown drain finished
        };
        let id = req.id;
        if deadline.expired() {
            state
                .metrics
                .deadline_exceeded
                .fetch_add(1, Ordering::Relaxed);
            let resp = Response::Error {
                id,
                kind: ErrorKind::DeadlineExceeded,
                message: format!(
                    "request waited {:.1} ms, past its deadline",
                    deadline.elapsed().as_secs_f64() * 1e3
                ),
            };
            drop(ticket); // free the admission slot before the reply blocks
            let _ = reply.send(resp);
            continue;
        }
        // Pin the epoch for the whole request: a concurrent reload swaps
        // the RwLock'd Arc, but this job finishes on the state it started
        // with.
        let hot = state.hot();
        match catch_unwind(AssertUnwindSafe(|| {
            score_request(&state, &hot, &req, &deadline)
        })) {
            Ok(resp) => {
                if matches!(resp, Response::Scores(_)) {
                    state.metrics.note_latency(deadline.elapsed());
                }
                drop(ticket); // free the admission slot before the reply blocks
                let _ = reply.send(resp);
            }
            Err(payload) => {
                state.metrics.panics.fetch_add(1, Ordering::Relaxed);
                state.metrics.internal_errors.fetch_add(1, Ordering::Relaxed);
                let msg = panic_message(payload.as_ref());
                drop(ticket);
                let _ = reply.send(Response::Error {
                    id,
                    kind: ErrorKind::Internal,
                    message: format!("worker panicked while scoring: {msg}"),
                });
                // The thread's state is suspect after an unwind through
                // scorer internals — exit and let the supervisor respawn.
                return;
            }
        }
    }
}

fn spawn_worker(state: Arc<ServerState>, rx: Arc<Mutex<Receiver<Job>>>) -> JoinHandle<()> {
    std::thread::spawn(move || worker_loop(state, rx))
}

/// The drain sequence — the single exit path shared by SIGTERM/SIGINT and
/// the protocol `shutdown` request. Queued jobs finish (workers drain the
/// closed channel), workers and open connections get `drain_ms` to wind
/// down, and the final report lands in `stats.drain` + stdout.
fn drain(state: &Arc<ServerState>, mut workers: Vec<JoinHandle<()>>) {
    let started = Instant::now();
    let budget = Duration::from_millis(state.cfg.drain_ms.max(1));
    let reason = lock_unpoisoned(&state.drain_reason)
        .clone()
        .unwrap_or_else(|| "shutdown".to_string());
    // Closing the channel is what ends the workers: queued jobs drain
    // (mpsc lets receivers finish buffered sends), then recv() errors.
    drop(lock_unpoisoned(&state.jobs).take());
    let total = workers.len();
    let mut joined = 0usize;
    let mut forced = false;
    loop {
        let mut remaining = Vec::new();
        for w in workers {
            if w.is_finished() {
                let _ = w.join();
                joined += 1;
            } else {
                remaining.push(w);
            }
        }
        workers = remaining;
        if workers.is_empty() {
            break;
        }
        if started.elapsed() >= budget {
            // Leak rather than block forever on a wedged scorer: the
            // process is exiting anyway, and the report says so.
            forced = true;
            break;
        }
        std::thread::sleep(ACCEPT_TICK);
    }
    // Sessions poll the shutdown flag between frames (their reads tick),
    // so open connections close themselves; give them the same budget.
    while state.metrics.active_connections() > 0 && started.elapsed() < budget {
        std::thread::sleep(ACCEPT_TICK);
    }
    let conns_left = state.metrics.active_connections();
    if conns_left > 0 {
        forced = true;
    }
    let report = Json::obj(vec![
        ("reason", Json::Str(reason.clone())),
        ("forced", Json::Bool(forced)),
        ("workers_total", Json::Num(total as f64)),
        ("workers_joined", Json::Num(joined as f64)),
        ("connections_at_exit", Json::Num(conns_left as f64)),
        (
            "elapsed_ms",
            Json::Num(started.elapsed().as_secs_f64() * 1e3),
        ),
    ]);
    *lock_unpoisoned(&state.drain_report) = Some(report);
    if !state.cfg.quiet {
        println!("serve: graceful shutdown ({reason}) — final metrics:");
        println!("{}", state.stats_json().to_string_pretty());
    }
}

/// A running daemon: bound address + join handle.
pub struct ServerHandle {
    addr: SocketAddr,
    accept: JoinHandle<()>,
}

impl ServerHandle {
    /// The actually bound address (resolves `:0` auto-assignment).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the daemon shuts down (signal or `shutdown` request).
    pub fn join(self) -> Result<()> {
        self.accept
            .join()
            .map_err(|_| anyhow!("serve accept thread panicked"))
    }
}

/// Build hot state, bind, and start serving in background threads.
/// Returns once the daemon is accepting connections.
///
/// Signal handlers are NOT installed here — embedders and tests keep
/// their process disposition; only the CLI path ([`run`]) installs them.
pub fn spawn(cfg: ServeConfig) -> Result<ServerHandle> {
    let hot = HotState::build(&cfg, &cfg.store, 1, None)?;
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    // Non-blocking accept: the loop has to poll the shutdown flag and the
    // signal box, and supervise workers, even when no client connects.
    listener
        .set_nonblocking(true)
        .context("setting the listener non-blocking")?;
    let state = Arc::new(ServerState {
        admission: Arc::new(Admission::new(cfg.max_in_flight)),
        metrics: Metrics::new(),
        hot: RwLock::new(Arc::new(hot)),
        store_opens: AtomicU64::new(1),
        reloading: AtomicBool::new(false),
        jobs: Mutex::new(None),
        shutdown: AtomicBool::new(false),
        drain_reason: Mutex::new(None),
        drain_report: Mutex::new(None),
        addr,
        cfg,
    });

    let (tx, rx) = mpsc::channel::<Job>();
    *lock_unpoisoned(&state.jobs) = Some(tx);
    let rx = Arc::new(Mutex::new(rx));
    let mut workers: Vec<JoinHandle<()>> = (0..state.cfg.workers.max(1))
        .map(|_| spawn_worker(state.clone(), rx.clone()))
        .collect();

    let accept_state = state.clone();
    let accept = std::thread::spawn(move || {
        loop {
            if let Some(sig) = signal::pending() {
                accept_state.begin_shutdown(sig);
            }
            if accept_state.shutdown.load(Ordering::Acquire) {
                break;
            }
            // Supervise: respawn any worker whose thread died (panic
            // escape hatch in worker_loop).
            for slot in workers.iter_mut() {
                if slot.is_finished() {
                    let dead = std::mem::replace(
                        slot,
                        spawn_worker(accept_state.clone(), rx.clone()),
                    );
                    let _ = dead.join();
                    accept_state.metrics.respawns.fetch_add(1, Ordering::Relaxed);
                }
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // The listener's non-blocking mode is inherited by
                    // accepted sockets on some platforms; sessions use
                    // timeouts, not non-blocking reads.
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let conn_state = accept_state.clone();
                    std::thread::spawn(move || {
                        crate::serve::session::handle_conn(stream, conn_state)
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_TICK);
                }
                Err(_) => std::thread::sleep(ACCEPT_TICK),
            }
        }
        drain(&accept_state, workers);
    });
    Ok(ServerHandle { addr, accept })
}

/// `grass serve` entry point: install signal handlers, spawn, announce,
/// and block until a signal or shutdown request drains the daemon.
pub fn run(cfg: ServeConfig) -> Result<()> {
    signal::install();
    let quiet = cfg.quiet;
    let store = cfg.store.clone();
    let scorers = cfg.scorers.clone();
    let drain_ms = cfg.drain_ms;
    let handle = spawn(cfg)?;
    if !quiet {
        println!(
            "serve: listening on {} (store {}, scorers {scorers:?}, simd {}) — SIGTERM/SIGINT \
             or `grass query --addr {} --shutdown` drains within {drain_ms} ms",
            handle.addr(),
            store.display(),
            crate::linalg::simd::active_isa(),
            handle.addr()
        );
    }
    handle.join()
}
