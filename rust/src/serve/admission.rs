//! Admission control: a bounded in-flight counter plus per-request
//! deadlines. Scoring work is only queued while a [`Ticket`] is held; when
//! the bound is hit, new requests are shed immediately with a typed
//! `Overloaded` reply instead of growing an unbounded backlog, and a
//! request whose latency budget expires before a worker picks it up gets a
//! typed `DeadlineExceeded` reply instead of stale scores.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bounded admission counter shared by every connection thread.
pub struct Admission {
    max_in_flight: usize,
    depth: AtomicUsize,
}

impl Admission {
    /// Admit at most `max_in_flight` queued-or-running score requests;
    /// `0` sheds everything (useful for deterministic overload tests).
    pub fn new(max_in_flight: usize) -> Self {
        Self {
            max_in_flight,
            depth: AtomicUsize::new(0),
        }
    }

    /// Try to admit one request. `None` means shed now.
    pub fn try_admit(self: &Arc<Self>) -> Option<Ticket> {
        let mut cur = self.depth.load(Ordering::Relaxed);
        loop {
            if cur >= self.max_in_flight {
                return None;
            }
            match self.depth.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Ticket { adm: self.clone() }),
                Err(now) => cur = now,
            }
        }
    }

    /// Currently admitted (queued + running) requests.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn max_in_flight(&self) -> usize {
        self.max_in_flight
    }
}

/// RAII admission slot: dropping it (reply sent, request shed mid-queue,
/// worker panicked out of scope) frees the slot.
pub struct Ticket {
    adm: Arc<Admission>,
}

impl Drop for Ticket {
    fn drop(&mut self) {
        self.adm.depth.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A request's latency budget, measured from arrival.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    budget: Option<Duration>,
}

impl Deadline {
    /// `request_ms` (per-request override) wins over `default_ms` (server
    /// config); a budget of 0 ms expires immediately, and a `default_ms`
    /// of 0 with no override means "no deadline".
    pub fn new(request_ms: Option<u64>, default_ms: u64) -> Self {
        let budget = match request_ms {
            Some(ms) => Some(Duration::from_millis(ms)),
            None if default_ms > 0 => Some(Duration::from_millis(default_ms)),
            None => None,
        };
        Self {
            start: Instant::now(),
            budget,
        }
    }

    pub fn expired(&self) -> bool {
        self.budget.is_some_and(|b| self.start.elapsed() >= b)
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tickets_bound_depth_and_release_on_drop() {
        let adm = Arc::new(Admission::new(2));
        let t1 = adm.try_admit().unwrap();
        let t2 = adm.try_admit().unwrap();
        assert_eq!(adm.depth(), 2);
        assert!(adm.try_admit().is_none(), "third request must shed");
        drop(t1);
        assert_eq!(adm.depth(), 1);
        let t3 = adm.try_admit().unwrap();
        assert!(adm.try_admit().is_none());
        drop(t2);
        drop(t3);
        assert_eq!(adm.depth(), 0);
    }

    #[test]
    fn zero_capacity_sheds_everything() {
        let adm = Arc::new(Admission::new(0));
        assert!(adm.try_admit().is_none());
        assert_eq!(adm.depth(), 0);
    }

    #[test]
    fn concurrent_admission_never_exceeds_bound() {
        let adm = Arc::new(Admission::new(4));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let adm = adm.clone();
                let peak = peak.clone();
                s.spawn(move || {
                    for _ in 0..200 {
                        if let Some(_t) = adm.try_admit() {
                            peak.fetch_max(adm.depth(), Ordering::Relaxed);
                            std::hint::spin_loop();
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 4);
        assert_eq!(adm.depth(), 0);
    }

    #[test]
    fn deadline_semantics() {
        assert!(Deadline::new(Some(0), 1000).expired(), "0 ms expires now");
        assert!(!Deadline::new(Some(10_000), 0).expired());
        assert!(!Deadline::new(None, 10_000).expired());
        let none = Deadline::new(None, 0);
        assert!(!none.expired(), "no budget never expires");
        let short = Deadline::new(Some(1), 0);
        std::thread::sleep(Duration::from_millis(5));
        assert!(short.expired());
    }
}
