//! Bench: the staged cache pipeline end-to-end (PJRT grad workers →
//! compress → store writer) on the MLP workload — the coordinator-level
//! throughput number (samples/s) that backs EXPERIMENTS.md §Perf.
//!
//! Two parts, both recorded in `BENCH_pipeline_e2e.json`:
//!
//! 1. **Compress stage** (always runs, no artifacts needed): the exact
//!    work stage 3 performs on one MLP-sized `GradBatch` — measured on the
//!    old per-sample `compress_into` loop and on the batch-first
//!    `compress_batch_with` kernel with per-worker scratch, at identical k.
//! 2. **Full pipeline** (requires `make artifacts`): PJRT gradient workers
//!    feeding the batch compress stage and the reordering store writer.
//!
//! Run: `cargo bench --bench pipeline_e2e`

use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::images::SynthDigits;
use grass::runtime::{Arg, Runtime};
use grass::sketch::rng::Pcg;
use grass::sketch::{Compressor, MethodSpec, Scratch};
use grass::util::bench::{self, BenchRecord};

/// The compress stage in isolation: one MLP-sized gradient block through
/// SJLT at the pipeline's default k, per-sample vs batch-first.
fn compress_stage_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let p = 84_618usize; // MLP parameter count (the pipeline's flat width)
    let n = if fast { 16 } else { 64 };
    let k = 1024usize;
    let mut rng = Pcg::new(11);
    // ~40% zeros: ReLU-induced per-sample gradient sparsity (paper §3.1)
    let gs: Vec<f32> = (0..n * p)
        .map(|_| {
            if rng.next_f32() < 0.4 {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
        .collect();
    let c = MethodSpec::Sjlt { k, s: 1 }.build(p, 42);
    let mut out = vec![0.0f32; n * k];
    let r_single = bench::bench(&format!("compress-stage per-sample n={n}"), || {
        for i in 0..n {
            c.compress_into(&gs[i * p..(i + 1) * p], &mut out[i * k..(i + 1) * k]);
        }
    });
    let mut scratch = Scratch::new();
    let r_batch = bench::bench(&format!("compress-stage batch n={n}"), || {
        c.compress_batch_with(&gs, n, &mut out, &mut scratch)
    });
    let speedup = r_single.median_secs() / r_batch.median_secs().max(1e-12);
    println!("== compress stage (SJLT k={k}, p={p}, n={n}) ==");
    println!("{}", r_single.report());
    println!("{}   <- batch speedup {speedup:.2}x", r_batch.report());
    records.push(BenchRecord::from_duration(
        "compress_stage:sjlt:k=1024:per_sample",
        n,
        p,
        k,
        r_single.median,
    ));
    records.push(
        BenchRecord::from_duration("compress_stage:sjlt:k=1024:batch", n, p, k, r_batch.median)
            .with("speedup_vs_per_sample", speedup),
    );
}

fn main() {
    let mut records: Vec<BenchRecord> = Vec::new();
    compress_stage_bench(&mut records);

    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("pipeline_e2e: skipping full pipeline (run `make artifacts` first)");
    } else {
        let rt = Runtime::load(dir).expect("runtime");
        let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
        let n = if fast { 64 } else { 512 };
        let p = rt.manifest.model("mlp").unwrap().p;
        let data = SynthDigits::generate(n, 3);
        let params = rt
            .executable("mlp_init")
            .unwrap()
            .run(&[Arg::ScalarI32(0)])
            .unwrap()
            .remove(0)
            .data;
        let store = std::env::temp_dir().join(format!("grass_bench_pipe_{}", std::process::id()));

        println!("== cache pipeline e2e (MLP, n = {n}) ==");
        for (gw, cw) in [(1usize, 1usize), (2, 2), (4, 2)] {
            let spec = MethodSpec::Sjlt { k: 1024, s: 1 };
            let bank = CompressorBank::Flat(spec.build(p, 42));
            let pipeline = CachePipeline::new(
                &rt,
                "mlp",
                params.clone(),
                PipelineConfig {
                    grad_workers: gw,
                    compress_workers: cw,
                    queue_depth: 4,
                    shard_rows: 4096,
                },
            );
            let _ = std::fs::remove_dir_all(&store);
            pipeline
                .run_flat(&Source::Labelled(&data), &bank, &store, "sjlt:k=1024,s=1", 42)
                .expect("pipeline");
            println!(
                "grad_workers={gw} compress_workers={cw}: {:.1} samples/s | {}",
                pipeline.metrics.samples_per_sec(),
                pipeline.metrics.report()
            );
            records.push(
                BenchRecord {
                    method: format!("pipeline:gw={gw}:cw={cw}:sjlt:k=1024"),
                    n,
                    p,
                    k: 1024,
                    samples_per_sec: pipeline.metrics.samples_per_sec(),
                    ns_per_elem: 1e9
                        / (pipeline.metrics.samples_per_sec() * p as f64).max(1e-12),
                    extra: vec![],
                },
            );
        }
        let _ = std::fs::remove_dir_all(&store);
    }

    match bench::write_bench_json("pipeline_e2e", &records) {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
