//! Bench: the staged cache pipeline end-to-end (PJRT grad workers →
//! compress → store writer) on the MLP workload — the coordinator-level
//! throughput number (samples/s) that backs EXPERIMENTS.md §Perf.
//!
//! Four parts, all recorded in `BENCH_pipeline_e2e.json`:
//!
//! 1. **Compress stage** (always runs, no artifacts needed): the exact
//!    work stage 3 performs on one MLP-sized `GradBatch` — measured on the
//!    old per-sample `compress_into` loop and on the batch-first
//!    `compress_batch_with` kernel with per-worker scratch, at identical k.
//! 2. **Streamed attribution** (always runs): a synthetic store 4× larger
//!    than the configured `--mem-budget`, scored out-of-core by the
//!    streaming influence engine at 1/2/4 workers. Asserts streamed ==
//!    in-memory scores (≤ 1e-5 rel) and that the configured resident
//!    buffer allocation stays within the budget.
//! 3. **Quantized streaming** (always runs): the same rows under f32 and
//!    f16 payload codecs scored by the dequant-fused streaming engine —
//!    asserts the 2× encoded bytes-per-row reduction and ≤ 1e-2 LDS drift
//!    that the CI quantization gate re-checks from the JSON.
//! 4. **Recovery** (always runs): an interrupted cache run resumed from
//!    its committed shards, then fault-injected streamed scoring whose
//!    transient read failures the retry policy absorbs — records
//!    `resume_skipped_rows` / `retries_attempted`.
//! 5. **Full pipeline** (requires `make artifacts`): PJRT gradient workers
//!    feeding the batch compress stage and the reordering store writer.
//!
//! Run: `cargo bench --bench pipeline_e2e`

use grass::attrib::blockwise::BlockLayout;
use grass::attrib::{
    Attributor, InfluenceEngine, PrecondArtifact, PrecondSpec, Preconditioner, StreamOpts,
};
use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::images::SynthDigits;
use grass::runtime::{Arg, Runtime};
use grass::sketch::rng::Pcg;
use grass::sketch::{Compressor, MethodSpec, Scratch};
use grass::store::{
    FaultKind, FaultPlan, PayloadDtype, RetryPolicy, StoreMeta, StoreReader, StoreWriter,
};
use grass::util::bench::{self, BenchRecord};

/// The compress stage in isolation: one MLP-sized gradient block through
/// SJLT at the pipeline's default k, per-sample vs batch-first.
fn compress_stage_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let p = 84_618usize; // MLP parameter count (the pipeline's flat width)
    let n = if fast { 16 } else { 64 };
    let k = 1024usize;
    let mut rng = Pcg::new(11);
    // ~40% zeros: ReLU-induced per-sample gradient sparsity (paper §3.1)
    let gs: Vec<f32> = (0..n * p)
        .map(|_| {
            if rng.next_f32() < 0.4 {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
        .collect();
    let c = MethodSpec::Sjlt { k, s: 1 }.build(p, 42);
    let mut out = vec![0.0f32; n * k];
    let r_single = bench::bench(&format!("compress-stage per-sample n={n}"), || {
        for i in 0..n {
            c.compress_into(&gs[i * p..(i + 1) * p], &mut out[i * k..(i + 1) * k]);
        }
    });
    let mut scratch = Scratch::new();
    let r_batch = bench::bench(&format!("compress-stage batch n={n}"), || {
        c.compress_batch_with(&gs, n, &mut out, &mut scratch)
    });
    let speedup = r_single.median_secs() / r_batch.median_secs().max(1e-12);
    println!("== compress stage (SJLT k={k}, p={p}, n={n}) ==");
    println!("{}", r_single.report());
    println!("{}   <- batch speedup {speedup:.2}x", r_batch.report());
    records.push(BenchRecord::from_duration(
        "compress_stage:sjlt:k=1024:per_sample",
        n,
        p,
        k,
        r_single.median,
    ));
    records.push(
        BenchRecord::from_duration("compress_stage:sjlt:k=1024:batch", n, p, k, r_batch.median)
            .with("speedup_vs_per_sample", speedup),
    );
}

/// Out-of-core streamed attribution on a store 4× larger than the memory
/// budget: correctness against the in-memory engine, then throughput
/// scaling over worker counts.
fn streaming_attribute_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (n, k, m) = if fast {
        (1024usize, 128usize, 8usize)
    } else {
        (8192, 256, 16)
    };
    let store_bytes = n * k * 4;
    let mem_budget = store_bytes / 4;
    let dir = std::env::temp_dir().join(format!("grass_bench_stream_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rng = Pcg::new(17);
    let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let mut w = StoreWriter::create(&dir, k, "bench", 0, 512).expect("store writer");
    w.push_batch(&rows).expect("push");
    w.finish().expect("finish");
    let queries: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();

    println!(
        "== streamed attribution (n={n}, k={k}: store {} KB vs budget {} KB) ==",
        store_bytes / 1024,
        mem_budget / 1024
    );
    let mut mem_engine = InfluenceEngine::new(k, 0.1);
    Attributor::cache(&mut mem_engine, &rows, n).expect("in-memory cache");
    let want = Attributor::attribute(&mem_engine, &queries, m).expect("in-memory attribute");
    let r_mem = bench::bench("attribute in-memory", || {
        let _ = Attributor::attribute(&mem_engine, &queries, m).unwrap();
    });
    println!("{}", r_mem.report());
    records.push(BenchRecord::from_duration(
        "attribute:in_memory:if",
        n,
        k,
        k,
        r_mem.median,
    ));

    let reader = StoreReader::open(&dir).expect("reader");
    let mut w1_secs = 0.0f64;
    for workers in [1usize, 2, 4] {
        let opts = StreamOpts {
            mem_budget,
            workers,
            ..StreamOpts::default()
        };
        // The acceptance bound: the configured resident buffer allocation
        // never exceeds the budget, while the store is 4× bigger.
        assert!(
            opts.resident_bytes(k) <= mem_budget,
            "resident {} bytes exceeds the {} byte budget",
            opts.resident_bytes(k),
            mem_budget
        );
        let mut eng = InfluenceEngine::new(k, 0.1);
        eng.cache_stream(&reader, &opts).expect("cache_stream");
        let got = Attributor::attribute(&eng, &queries, m).expect("streamed attribute");
        for i in 0..m * n {
            let (a, b) = (got.scores[i], want.scores[i]);
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "streamed mismatch at {i}: {a} vs {b}"
            );
        }
        let r = bench::bench(&format!("attribute streamed workers={workers}"), || {
            let _ = Attributor::attribute(&eng, &queries, m).unwrap();
        });
        if workers == 1 {
            w1_secs = r.median_secs();
        }
        let speedup = w1_secs / r.median_secs().max(1e-12);
        println!("{}   <- {speedup:.2}x vs 1 worker", r.report());
        records.push(
            BenchRecord::from_duration(
                &format!("attribute:streamed:if:w={workers}"),
                n,
                k,
                k,
                r.median,
            )
            .with("workers", workers as f64)
            .with("mem_budget_bytes", mem_budget as f64)
            .with("resident_bytes", opts.resident_bytes(k) as f64)
            .with("store_bytes", store_bytes as f64)
            .with("speedup_vs_1_worker", speedup),
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Quantized streamed scoring: the same rows cached under the f32 and f16
/// payload codecs, scored out-of-core by the streaming influence engine.
/// Asserts the encoded bytes-per-row reduction (2× for f16, the
/// bandwidth-bound gain the CI gate checks as ≥ 1.5×), that f16 scores
/// track f32 within the codec's error envelope, and that the LDS computed
/// from both score matrices over identical subsets drifts ≤ 1e-2. Records
/// `dtype`/`bytes_per_row` plus `lds_drift` so the gate reads everything
/// from `BENCH_pipeline_e2e.json`.
fn quantized_stream_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (n, k, m) = if fast {
        (1024usize, 128usize, 8usize)
    } else {
        (4096, 256, 16)
    };
    let mut rng = Pcg::new(41);
    let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let queries: Vec<f32> = (0..m * k).map(|_| rng.next_gaussian()).collect();
    let base = std::env::temp_dir().join(format!("grass_bench_quant_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    println!("== quantized streamed scoring: f32 vs f16 payloads (n={n}, k={k}) ==");
    let mut runs: Vec<(PayloadDtype, Vec<f32>, f64)> = Vec::new();
    for dtype in [PayloadDtype::F32, PayloadDtype::F16] {
        let dir = base.join(dtype.as_str());
        let meta = StoreMeta {
            k,
            n: 0,
            shard_rows: 512,
            method: "bench".to_string(),
            seed: 0,
            model: String::new(),
            input_dim: 0,
            layer_dims: vec![],
            density: 1.0,
            dtype,
        };
        let mut w = StoreWriter::create_described(&dir, meta).expect("writer");
        w.push_batch(&rows).expect("push");
        w.finish().expect("finish");
        let reader = StoreReader::open(&dir).expect("reader");
        let opts = StreamOpts::default();
        let mut eng = InfluenceEngine::new(k, 0.1);
        eng.cache_stream(&reader, &opts).expect("cache_stream");
        let got = Attributor::attribute(&eng, &queries, m).expect("attribute");
        // The measured cost is the dequant-fused streaming cache pass —
        // the phase whose byte traffic quantization halves.
        let r = bench::bench(&format!("cache_stream dtype={dtype}"), || {
            let mut eng = InfluenceEngine::new(k, 0.1);
            eng.cache_stream(&reader, &opts).unwrap();
        });
        println!("{}", r.report());
        runs.push((dtype, got.scores, r.median_secs()));
    }

    let (_, f32_scores, f32_secs) = &runs[0];
    let (_, f16_scores, f16_secs) = &runs[1];
    for i in 0..m * n {
        let (a, b) = (f16_scores[i], f32_scores[i]);
        assert!(
            (a - b).abs() <= 2e-2 * (1.0 + b.abs()),
            "f16 streamed score drifted at {i}: {a} vs f32 {b}"
        );
    }

    // LDS drift over identical subsets: ground-truth losses follow the
    // additive datamodel implied by the f32 scores, so f32 scores LDS ≈ 1
    // and the f16 delta isolates what quantization costs the ranking.
    let s_count = 32usize;
    let subsets = grass::eval::sample_subsets(n, s_count, 0.5, 43);
    let mut losses = vec![0.0f32; s_count * m];
    for (s, subset) in subsets.iter().enumerate() {
        for q in 0..m {
            losses[s * m + q] = -subset.iter().map(|&i| f32_scores[q * n + i]).sum::<f32>();
        }
    }
    let (lds_f32, _) = grass::eval::lds_score(f32_scores, n, m, &subsets, &losses);
    let (lds_f16, _) = grass::eval::lds_score(f16_scores, n, m, &subsets, &losses);
    let lds_drift = (lds_f32 - lds_f16).abs();
    assert!(
        lds_drift <= 1e-2,
        "f16 LDS drift {lds_drift:.4} exceeds 1e-2 (f32 {lds_f32:.4} vs f16 {lds_f16:.4})"
    );

    let bytes_f32 = PayloadDtype::F32.row_bytes(k) as f64;
    let bytes_f16 = PayloadDtype::F16.row_bytes(k) as f64;
    let bytes_ratio = bytes_f32 / bytes_f16;
    assert!(
        bytes_ratio >= 1.5,
        "f16 bytes-per-row reduction {bytes_ratio:.2}x is under the 1.5x gate"
    );
    let wall_speedup = f32_secs / f16_secs.max(1e-12);
    println!(
        "f16 vs f32: {bytes_ratio:.2}x fewer shard bytes/row, {wall_speedup:.2}x wall \
         (page-cached), LDS drift {lds_drift:.5}"
    );
    records.push(
        BenchRecord::from_duration(
            "stream:quant:f32:if",
            n,
            k,
            k,
            std::time::Duration::from_secs_f64(*f32_secs),
        )
        .with_dtype("f32", bytes_f32)
        .with("lds", lds_f32),
    );
    records.push(
        BenchRecord::from_duration(
            "stream:quant:f16:if",
            n,
            k,
            k,
            std::time::Duration::from_secs_f64(*f16_secs),
        )
        .with_dtype("f16", bytes_f16)
        .with("lds", lds_f16)
        .with("lds_drift", lds_drift)
        .with("bytes_ratio_vs_f32", bytes_ratio)
        .with("wall_speedup_vs_f32", wall_speedup),
    );
    let _ = std::fs::remove_dir_all(&base);
}

/// Preconditioner fit/apply costs: the stream-FIM fit pass vs loading the
/// persisted `precond.bin` artifact (which skips the row stream entirely),
/// plus the per-row apply cost. Records `precond_fit_ms`/`precond_apply_ms`
/// so the solver cost trajectory is diffable across PRs; CI asserts the
/// artifact path beats the refit.
fn precond_artifact_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (n, k) = if fast { (1024usize, 96usize) } else { (4096, 192) };
    let dir = std::env::temp_dir().join(format!("grass_bench_precond_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rng = Pcg::new(23);
    let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let mut w = StoreWriter::create(&dir, k, "bench", 0, 512).expect("store writer");
    w.push_batch(&rows).expect("push");
    w.finish().expect("finish");
    let reader = StoreReader::open(&dir).expect("reader");
    let layout = BlockLayout::new(vec![k]);
    let opts = StreamOpts::default();
    let spec = PrecondSpec::Damped { lambda: 0.1 };

    println!("== preconditioner fit: stream-FIM refit vs persisted artifact (n={n}, k={k}) ==");
    let r_fit = bench::bench("precond fit (stream FIM pass)", || {
        let _ = bench::black_box(PrecondArtifact::fit(&reader, &opts, &layout).unwrap());
    });
    let artifact = PrecondArtifact::fit(&reader, &opts, &layout).expect("fit");
    artifact.save(&dir).expect("save artifact");
    let r_load = bench::bench("precond fit (load artifact + build)", || {
        let a = PrecondArtifact::load(&dir).unwrap();
        let _ = bench::black_box(spec.build(&a.fims, &layout).unwrap());
    });
    let pre = spec.build(&artifact.fims, &layout).expect("build");
    let mut buf = rows.clone();
    let r_apply = bench::bench("precond apply_rows", || {
        buf.copy_from_slice(&rows);
        pre.apply_rows(&mut buf, n);
    });
    let speedup = r_fit.median_secs() / r_load.median_secs().max(1e-12);
    println!("{}", r_fit.report());
    println!("{}   <- artifact reuse {speedup:.1}x vs refit", r_load.report());
    println!("{}", r_apply.report());
    let apply_ms = r_apply.median_secs() * 1e3;
    records.push(
        BenchRecord::from_duration("precond:fit_stream", n, k, k, r_fit.median)
            .with_precond(r_fit.median_secs() * 1e3, apply_ms),
    );
    records.push(
        BenchRecord::from_duration("precond:fit_artifact", n, k, k, r_load.median)
            .with_precond(r_load.median_secs() * 1e3, apply_ms)
            .with("speedup_vs_refit", speedup),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fault-tolerance stage: an interrupted cache run resumed from its
/// committed shards (the resumed writer recomputes only the missing rows),
/// then a fault-injected streamed scoring pass whose transient shard-read
/// failures the retry policy absorbs. Records `resume_skipped_rows` /
/// `retries_attempted` so the recovery cost trajectory is diffable.
fn recovery_bench(records: &mut Vec<BenchRecord>) {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (n, k, shard_rows, m) = if fast {
        (512usize, 64usize, 64usize, 4usize)
    } else {
        (2048, 128, 256, 8)
    };
    let dir = std::env::temp_dir().join(format!("grass_bench_recover_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut rng = Pcg::new(29);
    let rows: Vec<f32> = (0..n * k).map(|_| rng.next_gaussian()).collect();
    let meta = StoreMeta {
        k,
        n: 0,
        shard_rows,
        method: "bench".to_string(),
        seed: 0,
        model: String::new(),
        input_dim: 0,
        layer_dims: vec![],
        density: 1.0,
        dtype: PayloadDtype::F32,
    };

    // Interrupted run: push the first half, then drop the writer without
    // `finish` — as after a crash, only manifest-listed shards survive.
    let mut w = StoreWriter::create_described(&dir, meta.clone()).expect("writer");
    w.push_batch(&rows[..(n / 2) * k]).expect("push half");
    drop(w);

    let ((committed, retries), d) = bench::time_once(|| {
        let (mut w, committed) = StoreWriter::resume(&dir, &meta).expect("resume");
        w.push_batch(&rows[committed * k..]).expect("push rest");
        w.finish().expect("finish");

        // Score the recovered store with two injected transient read
        // faults on shard 1; the retry policy absorbs both.
        let mut reader = StoreReader::open(&dir).expect("reader");
        let plan = FaultPlan::new();
        plan.fail_read(1, FaultKind::Transient, 0, 2);
        reader.inject_faults(plan);
        let opts = StreamOpts {
            retry: RetryPolicy {
                retries: 3,
                backoff: std::time::Duration::from_millis(1),
                seed: 0,
            },
            ..StreamOpts::default()
        };
        let mut eng = InfluenceEngine::new(k, 0.1);
        eng.cache_stream(&reader, &opts).expect("cache_stream under faults");
        let queries: Vec<f32> = rows[..m * k].to_vec();
        let _ = Attributor::attribute(&eng, &queries, m).expect("attribute under faults");
        (committed, opts.log.retries_attempted())
    });
    println!("== recovery (n={n}, k={k}, shard_rows={shard_rows}) ==");
    println!(
        "resume skipped {committed} committed rows; {retries} shard-read \
         retries absorbed; stage took {}",
        bench::fmt_dur(d)
    );
    records.push(
        BenchRecord::from_duration("recovery:resume+retry:if", n, k, k, d)
            .with_recovery(committed as u64, retries),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Serving stage: an in-process daemon (ephemeral port, warm shard cache)
/// answering synthetic score requests over one connection. Records QPS and
/// the daemon's own p50/p95/p99 latency + shard-cache hit rate (pulled
/// from a `stats` request) so the serving trajectory is diffable.
fn serve_bench(records: &mut Vec<BenchRecord>) {
    use grass::serve::proto::{self, QueryPayload, Request, Response, ScoreRequest};
    use grass::serve::{self, ServeConfig};
    use std::io::{BufReader, BufWriter};

    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (n, p, requests) = if fast {
        (256usize, 512usize, 16usize)
    } else {
        (1024, 2048, 64)
    };
    let k = 64usize;
    let dir = std::env::temp_dir().join(format!("grass_bench_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A flat synthetic store the daemon accepts (model = "synth").
    let spec = MethodSpec::Sjlt { k, s: 1 };
    let shapes = grass::models::shapes::ModelShapes::flat(p);
    let bank = CompressorBank::Flat(spec.build(p, 11));
    let c = bank.as_flat().unwrap();
    let meta = StoreMeta::describe(&spec, 11, "synth", &shapes, 128).expect("meta");
    let mut w = StoreWriter::create_described(&dir, meta).expect("writer");
    let src = grass::data::synthgrad::SynthGrads::new(p, 11);
    let rows = src.rows(0, n);
    let mut out = vec![0.0f32; n * k];
    let mut scratch = Scratch::new();
    c.compress_batch_with(&rows, n, &mut out, &mut scratch);
    w.push_batch(&out).expect("push");
    w.finish().expect("finish");

    let handle = serve::spawn(ServeConfig {
        store: dir.clone(),
        addr: "127.0.0.1:0".to_string(),
        scorers: vec!["graddot".to_string()],
        workers: 2,
        cache_bytes: 64 << 20,
        quiet: true,
        ..ServeConfig::default()
    })
    .expect("spawn daemon");
    let addr = handle.addr();
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut writer = BufWriter::new(stream.try_clone().expect("clone"));
    let mut reader = BufReader::new(stream);
    let mut ask = |req: Request| -> Response {
        proto::write_frame(&mut writer, &req.to_line()).expect("write frame");
        let frame = proto::read_frame(&mut reader)
            .expect("read frame")
            .expect("daemon replied");
        Response::from_json(&frame).expect("parse response")
    };

    let m = 4usize;
    let (_, d) = bench::time_once(|| {
        for i in 0..requests {
            let resp = ask(Request::Score(ScoreRequest {
                id: i as u64 + 1,
                scorer: "graddot".to_string(),
                top_k: 5,
                include_scores: false,
                self_influence: false,
                deadline_ms: None,
                queries: QueryPayload::Synth { m },
            }));
            match resp {
                Response::Scores(r) => assert_eq!(r.m, m),
                other => panic!("unexpected daemon reply: {:?}", other.to_json()),
            }
        }
    });
    let qps = requests as f64 / d.as_secs_f64().max(1e-12);

    // Resilience probe: a burst of already-expired requests must shed
    // with typed replies (never a dropped connection). Availability is
    // the fraction of all offered score requests answered with scores —
    // here exactly requests / (requests + burst) when nothing else fails.
    let burst = 8usize;
    for i in 0..burst {
        let resp = ask(Request::Score(ScoreRequest {
            id: 9000 + i as u64,
            scorer: "graddot".to_string(),
            top_k: 5,
            include_scores: false,
            self_influence: false,
            deadline_ms: Some(0),
            queries: QueryPayload::Synth { m },
        }));
        match resp {
            Response::Error { kind, .. } => assert!(kind.is_shed(), "{kind:?}"),
            other => panic!("expired request must shed typed: {:?}", other.to_json()),
        }
    }

    let stats = match ask(Request::Stats { id: 0 }) {
        Response::Stats { stats, .. } => stats,
        other => panic!("unexpected stats reply: {:?}", other.to_json()),
    };
    let lat = stats.req("latency").expect("latency");
    let pick = |key: &str| lat.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let (p50, p95, p99) = (pick("p50_ms"), pick("p95_ms"), pick("p99_ms"));
    let hit_rate = stats
        .get("shard_cache")
        .and_then(|s| s.get("hit_rate"))
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    let req_stats = stats.req("requests").expect("requests");
    let req_stat = |key: &str| req_stats.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let sheds = req_stat("overloaded") + req_stat("deadline_exceeded");
    let offered = (requests + burst) as f64;
    let availability = req_stat("scored") / offered.max(1.0);

    match ask(Request::Shutdown { id: 0 }) {
        Response::ShuttingDown { .. } => {}
        other => panic!("unexpected shutdown reply: {:?}", other.to_json()),
    }
    drop(reader);
    drop(writer);
    handle.join().expect("serve daemon shutdown");

    println!("== serving daemon (n={n}, k={k}, {requests} requests × {m} queries) ==");
    println!(
        "{qps:.1} req/s | p50 {p50:.2} ms p95 {p95:.2} ms p99 {p99:.2} ms | \
         shard-cache hit rate {hit_rate:.3} | availability {availability:.3} \
         ({sheds:.0} typed sheds)"
    );
    records.push(
        BenchRecord::from_duration("serve:graddot:synth", requests * m, k, k, d / requests as u32)
            .with_serving(qps, p50, p95, p99)
            .with_cache_hit_rate(hit_rate)
            .with_availability(availability, sheds as u64),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

fn main() {
    let mut records: Vec<BenchRecord> = Vec::new();
    compress_stage_bench(&mut records);
    streaming_attribute_bench(&mut records);
    quantized_stream_bench(&mut records);
    precond_artifact_bench(&mut records);
    recovery_bench(&mut records);
    serve_bench(&mut records);

    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("pipeline_e2e: skipping full pipeline (run `make artifacts` first)");
    } else {
        let rt = Runtime::load(dir).expect("runtime");
        let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
        let n = if fast { 64 } else { 512 };
        let p = rt.manifest.model("mlp").unwrap().p;
        let data = SynthDigits::generate(n, 3);
        let params = rt
            .executable("mlp_init")
            .unwrap()
            .run(&[Arg::ScalarI32(0)])
            .unwrap()
            .remove(0)
            .data;
        let store = std::env::temp_dir().join(format!("grass_bench_pipe_{}", std::process::id()));

        println!("== cache pipeline e2e (MLP, n = {n}) ==");
        for (gw, cw) in [(1usize, 1usize), (2, 2), (4, 2)] {
            let spec = MethodSpec::Sjlt { k: 1024, s: 1 };
            let bank = CompressorBank::Flat(spec.build(p, 42));
            let pipeline = CachePipeline::new(
                &rt,
                "mlp",
                params.clone(),
                PipelineConfig {
                    grad_workers: gw,
                    compress_workers: cw,
                    queue_depth: 4,
                    shard_rows: 4096,
                    ..PipelineConfig::default()
                },
            );
            let _ = std::fs::remove_dir_all(&store);
            pipeline
                .run_flat(&Source::Labelled(&data), &bank, &store, "sjlt:k=1024,s=1", 42)
                .expect("pipeline");
            println!(
                "grad_workers={gw} compress_workers={cw}: {:.1} samples/s | {}",
                pipeline.metrics.samples_per_sec(),
                pipeline.metrics.report()
            );
            records.push(
                BenchRecord {
                    method: format!("pipeline:gw={gw}:cw={cw}:sjlt:k=1024"),
                    n,
                    p,
                    k: 1024,
                    samples_per_sec: pipeline.metrics.samples_per_sec(),
                    ns_per_elem: 1e9
                        / (pipeline.metrics.samples_per_sec() * p as f64).max(1e-12),
                    density: Some(pipeline.metrics.input_density()),
                    mean_nnz: Some(pipeline.metrics.input_density() * p as f64),
                    precond_fit_ms: None,
                    precond_apply_ms: None,
                    resume_skipped_rows: None,
                    retries_attempted: None,
                    qps: None,
                    p50_ms: None,
                    p95_ms: None,
                    p99_ms: None,
                    cache_hit_rate: None,
                    availability: None,
                    sheds: None,
                    dtype: None,
                    bytes_per_row: None,
                    extra: vec![],
                },
            );
        }
        let _ = std::fs::remove_dir_all(&store);
    }

    match bench::write_bench_json("pipeline_e2e", &records) {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
