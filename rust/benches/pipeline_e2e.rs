//! Bench: the staged cache pipeline end-to-end (PJRT grad workers →
//! compress → store writer) on the MLP workload — the coordinator-level
//! throughput number (samples/s) that backs EXPERIMENTS.md §Perf.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench pipeline_e2e`

use grass::coordinator::{pipeline::Source, CachePipeline, CompressorBank, PipelineConfig};
use grass::data::images::SynthDigits;
use grass::runtime::{Arg, Runtime};
use grass::sketch::MethodSpec;

fn main() {
    let dir = Runtime::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("pipeline_e2e: skipping (run `make artifacts` first)");
        return;
    }
    let rt = Runtime::load(dir).expect("runtime");
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let n = if fast { 64 } else { 512 };
    let p = rt.manifest.model("mlp").unwrap().p;
    let data = SynthDigits::generate(n, 3);
    let params = rt
        .executable("mlp_init")
        .unwrap()
        .run(&[Arg::ScalarI32(0)])
        .unwrap()
        .remove(0)
        .data;
    let store = std::env::temp_dir().join(format!("grass_bench_pipe_{}", std::process::id()));

    println!("== cache pipeline e2e (MLP, n = {n}) ==");
    for (gw, cw) in [(1usize, 1usize), (2, 2), (4, 2)] {
        let spec = MethodSpec::Sjlt { k: 1024, s: 1 };
        let bank = CompressorBank::Flat(spec.build(p, 42));
        let pipeline = CachePipeline::new(
            &rt,
            "mlp",
            params.clone(),
            PipelineConfig {
                grad_workers: gw,
                compress_workers: cw,
                queue_depth: 4,
                shard_rows: 4096,
            },
        );
        let _ = std::fs::remove_dir_all(&store);
        pipeline
            .run_flat(&Source::Labelled(&data), &bank, &store, "sjlt:k=1024,s=1", 42)
            .expect("pipeline");
        println!(
            "grad_workers={gw} compress_workers={cw}: {:.1} samples/s | {}",
            pipeline.metrics.samples_per_sec(),
            pipeline.metrics.report()
        );
    }
    let _ = std::fs::remove_dir_all(&store);
}
