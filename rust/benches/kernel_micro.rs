//! Bench: per-kernel scalar-vs-SIMD A/B over the `linalg::simd` layer —
//! every dispatched hot loop measured twice through the same closure,
//! once pinned to the scalar reference (`set_simd_enabled(false)`) and
//! once on the detected ISA. Emits `BENCH_kernel_micro.json` with
//! per-kernel GB/s on both paths and the speedup; CI gates the `dot4x4`
//! and `decode_f16` speedups at ≥ 1.5× on AVX2 runners (the JSON's
//! top-level `simd_isa` says which kernel path the run dispatched to, so
//! the gate can skip itself with a logged reason on scalar-only hosts).
//!
//! Run: `cargo bench --bench kernel_micro`
//! Env: GRASS_BENCH_FAST=1 shrinks the workloads;
//!      GRASS_BENCH_BUDGET_MS caps each measurement;
//!      GRASS_NO_SIMD=1 collapses both sides to the scalar path.

use grass::linalg::fwht::fwht_inplace;
use grass::linalg::quantize::{f32_to_bf16_bits, f32_to_f16_bits};
use grass::linalg::simd;
use grass::sketch::rng::Pcg;
use grass::store::PayloadDtype;
use grass::util::bench::{self, black_box, BenchRecord};
use std::time::Duration;

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    (0..n).map(|_| rng.next_gaussian()).collect()
}

/// Measure one closure on the scalar path, then on the detected ISA.
fn ab<F: FnMut()>(label: &str, mut f: F) -> (Duration, Duration) {
    simd::set_simd_enabled(false);
    let scalar = bench::bench(&format!("{label} [scalar]"), &mut f);
    simd::set_simd_enabled(true);
    let active = bench::bench(&format!("{label} [{}]", simd::active_isa()), &mut f);
    println!("{}", scalar.report());
    println!("{}", active.report());
    (scalar.median, active.median)
}

/// One JSON record per kernel: bytes-touched throughput on both paths
/// plus the scalar→SIMD speedup the CI gate reads.
fn record(
    records: &mut Vec<BenchRecord>,
    name: &str,
    elems: usize,
    bytes: f64,
    scalar: Duration,
    active: Duration,
) {
    let gb = |d: Duration| bytes / d.as_secs_f64().max(1e-12) / 1e9;
    let speedup = scalar.as_secs_f64() / active.as_secs_f64().max(1e-12);
    println!(
        "  {name}: {:.2} → {:.2} GB/s ({speedup:.2}×)",
        gb(scalar),
        gb(active)
    );
    records.push(
        BenchRecord::from_duration(&format!("kernel:{name}"), 1, elems, elems, active)
            .with("scalar_gb_s", gb(scalar))
            .with("simd_gb_s", gb(active))
            .with("speedup", speedup),
    );
}

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let mut records: Vec<BenchRecord> = Vec::new();

    // GEMM microkernel: one 4×4 output tile over a long shared dimension,
    // the inner loop of every matmul in the scorers.
    {
        let kdim = if fast { 1024 } else { 4096 };
        let reps = 32;
        let a = gaussian(4 * kdim, 1);
        let b = gaussian(4 * kdim, 2);
        let ar = [
            &a[..kdim],
            &a[kdim..2 * kdim],
            &a[2 * kdim..3 * kdim],
            &a[3 * kdim..],
        ];
        let br = [
            &b[..kdim],
            &b[kdim..2 * kdim],
            &b[2 * kdim..3 * kdim],
            &b[3 * kdim..],
        ];
        let (s, v) = ab("dot4x4", || {
            for _ in 0..reps {
                let mut acc = [[0.0f32; 4]; 4];
                simd::dot4x4(ar, br, kdim, &mut acc);
                black_box(&acc);
            }
        });
        let bytes = (reps * 8 * kdim * 4) as f64;
        record(&mut records, "dot4x4", 8 * kdim, bytes, s, v);
    }

    // axpy: the rank-1 update in the tall-skinny matmul tail paths.
    {
        let n = if fast { 1 << 14 } else { 1 << 16 };
        let reps = 16;
        let b = gaussian(n, 3);
        let mut c = gaussian(n, 4);
        let (s, v) = ab("axpy", || {
            for _ in 0..reps {
                simd::axpy(&mut c, 1.000001, &b);
            }
            black_box(&c);
        });
        let bytes = (reps * n * 12) as f64;
        record(&mut records, "axpy", n, bytes, s, v);
    }

    // Mask gather: RandomMask / GraSS stage 1 (`out[i] = src[idx[i]]·s`).
    {
        let p = if fast { 1 << 16 } else { 1 << 18 };
        let k = p / 16;
        let reps = 16;
        let src = gaussian(p, 5);
        let idx = Pcg::new(6).sample_distinct(p, k);
        let mut out = vec![0.0f32; k];
        let (s, v) = ab("gather_scale", || {
            for _ in 0..reps {
                simd::gather_scale(&src, &idx, 0.5, &mut out);
            }
            black_box(&out);
        });
        let bytes = (reps * k * 8) as f64;
        record(&mut records, "gather_scale", k, bytes, s, v);
    }

    // SJLT scatter: one dense coordinate chunk through the (bucket, sign)
    // table, half the inputs zero (the vector win is the 8-wide zero-skip).
    {
        let chunk = 4096;
        let k = 2048;
        let sreps = 2usize;
        let reps = if fast { 16 } else { 64 };
        let mut rng = Pcg::new(7);
        let g: Vec<f32> = (0..chunk)
            .map(|_| {
                if rng.next_f32() < 0.5 {
                    0.0
                } else {
                    rng.next_gaussian()
                }
            })
            .collect();
        let table: Vec<(u32, f32)> = (0..chunk * sreps)
            .map(|_| {
                let b = (rng.next_u64() % k as u64) as u32;
                let sgn = if rng.next_f32() < 0.5 { -1.0 } else { 1.0 };
                (b, sgn)
            })
            .collect();
        let mut acc = vec![0.0f32; k];
        let (s, v) = ab("sjlt_scatter", || {
            for _ in 0..reps {
                simd::sjlt_scatter(&g, &table, sreps, &mut acc);
            }
            black_box(&acc);
        });
        let bytes = (reps * chunk * 4) as f64;
        record(&mut records, "sjlt_scatter", chunk, bytes, s, v);
    }

    // FWHT: the full transform (log n butterfly sweeps + the 1/√n scale),
    // measured through its real entry point.
    {
        let n = if fast { 1 << 12 } else { 1 << 14 };
        let reps = 8;
        let mut x = gaussian(n, 8);
        let stages = n.trailing_zeros() as usize;
        let (s, v) = ab("fwht", || {
            for _ in 0..reps {
                fwht_inplace(&mut x);
            }
            black_box(&x);
        });
        let bytes = (reps * n * stages * 8) as f64;
        record(&mut records, "fwht", n, bytes, s, v);
    }

    // Payload decoders: the dequant-fused shard read path.
    let n = if fast { 1 << 14 } else { 1 << 16 };
    let vals = gaussian(n, 9);
    {
        let bytes: Vec<u8> = vals
            .iter()
            .flat_map(|&x| f32_to_f16_bits(x).to_le_bytes())
            .collect();
        let mut out = vec![0.0f32; n];
        let reps = 16;
        let (s, v) = ab("decode_f16", || {
            for _ in 0..reps {
                simd::decode_f16(&bytes, &mut out);
            }
            black_box(&out);
        });
        let moved = (reps * n * 6) as f64;
        record(&mut records, "decode_f16", n, moved, s, v);
    }
    {
        let bytes: Vec<u8> = vals
            .iter()
            .flat_map(|&x| f32_to_bf16_bits(x).to_le_bytes())
            .collect();
        let mut out = vec![0.0f32; n];
        let reps = 16;
        let (s, v) = ab("decode_bf16", || {
            for _ in 0..reps {
                simd::decode_bf16(&bytes, &mut out);
            }
            black_box(&out);
        });
        let moved = (reps * n * 6) as f64;
        record(&mut records, "decode_bf16", n, moved, s, v);
    }

    // Row-framed int8 decode: per-row scale header + k codes per frame,
    // through the same `decode_rows` entry the warm-cache read path uses.
    {
        let k = 1024;
        let rows = n / k;
        let dt = PayloadDtype::Int8;
        let mut enc = Vec::with_capacity(rows * dt.row_bytes(k));
        for row in vals.chunks(k) {
            dt.encode_row(row, &mut enc);
        }
        let mut out = vec![0.0f32; rows * k];
        let reps = 16;
        let (s, v) = ab("decode_rows:int8", || {
            for _ in 0..reps {
                dt.decode_rows(&enc, k, rows, &mut out);
            }
            black_box(&out);
        });
        let moved = (reps * rows * (dt.row_bytes(k) + 4 * k)) as f64;
        record(&mut records, "decode_rows_int8", rows * k, moved, s, v);
    }

    // The A/B loop leaves SIMD enabled, so the JSON's top-level
    // `simd_isa` names the path the "simd_gb_s" numbers ran on.
    match bench::write_bench_json("kernel_micro", &records) {
        Ok(path) => println!("bench json: {}", path.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
