//! Bench: Table 1 compression wall-time columns — every compressor over an
//! MLP-scale gradient batch (P = 84,618), reproducing the time ordering of
//! Tables 1a–c: masks ≪ GraSS ≪ SJLT ≪ FJLT ≪ Gauss.
//!
//! Run: `cargo bench --bench table1_compression`

use grass::sketch::rng::Pcg;
use grass::sketch::{MaskKind, MethodSpec};
use grass::util::bench;

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let p = 84_618usize; // MLP parameter count
    let n = if fast { 8 } else { 64 };
    let ks: &[usize] = if fast { &[512] } else { &[512, 1024, 2048] };
    let mut rng = Pcg::new(5);
    // ~40% zeros, matching the ReLU-induced per-sample gradient sparsity
    // observed on the trained MLP (paper §3.1).
    let gs: Vec<f32> = (0..n * p)
        .map(|_| {
            if rng.next_f32() < 0.4 {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
        .collect();
    println!("== Table 1 compression benchmark (P = {p}, batch = {n}) ==");
    // Ablation: SJLT sparsity parameter s (paper default s = 1).
    {
        let k = ks[0];
        for s in [1usize, 2, 4, 8] {
            let c = MethodSpec::Sjlt { k, s }.build(p, 42);
            let mut out = vec![0.0f32; n * k];
            let r = bench::bench(&format!("ablation SJLT s={s} k={k}"), || {
                c.compress_batch(&gs, n, &mut out)
            });
            println!("{}", r.report());
        }
    }
    for &k in ks {
        let specs = vec![
            MethodSpec::RandomMask { k },
            MethodSpec::Sjlt { k, s: 1 },
            MethodSpec::Grass {
                k,
                k_prime: (4 * k).min(p),
                mask: MaskKind::Random,
            },
            MethodSpec::Fjlt { k },
            MethodSpec::Gauss { k },
        ];
        for spec in specs {
            let c = spec.build(p, 42);
            let mut out = vec![0.0f32; n * k];
            let r = bench::bench(&format!("{} batch={n}", c.name()), || {
                c.compress_batch(&gs, n, &mut out)
            });
            println!("{}", r.report());
        }
    }
}

// Note: an `s`-sweep ablation for SJLT (paper fixes s = 1) is provided by
// the library test-bench below; run with `cargo bench --bench
// table1_compression` and compare the SJLT rows.
