//! Bench: Table 1 compression wall-time columns — every compressor over an
//! MLP-scale gradient batch (P = 84,618), reproducing the time ordering of
//! Tables 1a–c: masks ≪ GraSS ≪ SJLT ≪ FJLT ≪ Gauss.
//!
//! Each method is measured on both execution models at identical k:
//! the per-sample `compress_into` loop (the old compress-stage baseline)
//! and the batch-first `compress_batch_with` kernel with a reusable
//! scratch. Results land in `BENCH_table1_compression.json`.
//!
//! Run: `cargo bench --bench table1_compression`

use grass::sketch::rng::Pcg;
use grass::sketch::{Compressor, MaskKind, MethodSpec, Scratch};
use grass::util::bench::{self, BenchRecord};

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let p = 84_618usize; // MLP parameter count
    let n = if fast { 8 } else { 64 };
    // The per-sample baseline runs fewer rows (its cost is linear in rows;
    // Gauss at k=2048 is ~1 s/row) and is normalised per sample.
    let n_base = n.min(8);
    let ks: &[usize] = if fast { &[512] } else { &[512, 1024, 2048] };
    let mut rng = Pcg::new(5);
    // ~40% zeros, matching the ReLU-induced per-sample gradient sparsity
    // observed on the trained MLP (paper §3.1).
    let gs: Vec<f32> = (0..n * p)
        .map(|_| {
            if rng.next_f32() < 0.4 {
                0.0
            } else {
                rng.next_gaussian()
            }
        })
        .collect();
    println!("== Table 1 compression benchmark (P = {p}, batch = {n}) ==");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut scratch = Scratch::new();
    // Ablation: SJLT sparsity parameter s (paper default s = 1).
    {
        let k = ks[0];
        for s in [1usize, 2, 4, 8] {
            let c = MethodSpec::Sjlt { k, s }.build(p, 42);
            let mut out = vec![0.0f32; n * k];
            let r = bench::bench(&format!("ablation SJLT s={s} k={k}"), || {
                c.compress_batch_with(&gs, n, &mut out, &mut scratch)
            });
            println!("{}", r.report());
            records.push(
                BenchRecord::from_duration(&format!("sjlt:k={k},s={s}:batch"), n, p, k, r.median)
                    .with("s", s as f64),
            );
        }
    }
    for &k in ks {
        let specs = vec![
            MethodSpec::RandomMask { k },
            MethodSpec::Sjlt { k, s: 1 },
            MethodSpec::Grass {
                k,
                k_prime: (4 * k).min(p),
                mask: MaskKind::Random,
            },
            MethodSpec::Fjlt { k },
            MethodSpec::Gauss { k },
        ];
        for spec in specs {
            let c = spec.build(p, 42);
            let mut out = vec![0.0f32; n * k];
            // per-sample baseline: the old compress-stage inner loop
            let r_single = bench::bench(&format!("{} per-sample n={n_base}", c.name()), || {
                for i in 0..n_base {
                    c.compress_into(&gs[i * p..(i + 1) * p], &mut out[i * k..(i + 1) * k]);
                }
            });
            // batch-first kernel over the full batch with reusable scratch
            let r_batch = bench::bench(&format!("{} batch={n}", c.name()), || {
                c.compress_batch_with(&gs, n, &mut out, &mut scratch)
            });
            let per_sample_single = r_single.median_secs() / n_base as f64;
            let per_sample_batch = r_batch.median_secs() / n as f64;
            let speedup = per_sample_single / per_sample_batch.max(1e-12);
            println!("{}", r_single.report());
            println!("{}   <- batch speedup {speedup:.2}x", r_batch.report());
            records.push(BenchRecord::from_duration(
                &format!("{}:per_sample", spec.spec_string()),
                n_base,
                p,
                k,
                r_single.median,
            ));
            records.push(
                BenchRecord::from_duration(
                    &format!("{}:batch", spec.spec_string()),
                    n,
                    p,
                    k,
                    r_batch.median,
                )
                .with("speedup_vs_per_sample", speedup),
            );
        }
    }
    match bench::write_bench_json("table1_compression", &records) {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
