//! Bench: Table 2 — FactGraSS vs LoGra throughput on the exact
//! Llama-3.1-8B layer geometry. Prints the same rows as the paper.
//!
//! Run: `cargo bench --bench table2_throughput`

use grass::exp::table2;

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (kls, tokens, reps) = if fast {
        (vec![256], 64, 2)
    } else {
        (vec![256, 1024, 4096], 256, 4)
    };
    let table = table2::run(&kls, tokens, reps, Some("results/table2.json")).expect("table2");
    table.print();
}
