//! Bench: Table 2 — FactGraSS vs LoGra throughput on the exact
//! Llama-3.1-8B layer geometry, on both execution models (per-sample
//! `compress_into` loop vs the batch-first kernels), plus a density sweep
//! pitting the dense batch kernels against the CSR (sparse) kernels at
//! identical `(p, k, s)`. Prints the same rows as the paper plus the
//! batch-speedup column, and persists `BENCH_table2_throughput.json`
//! (records carry `density` / `mean_nnz` / `sparse_speedup` so the
//! nnz-proportional scaling is diffable across PRs — CI asserts the
//! sparse path wins at 1% density).
//!
//! Run: `cargo bench --bench table2_throughput`

use grass::exp::table2;
use grass::util::bench;

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let (kls, tokens, reps, batch) = if fast {
        (vec![256], 64, 2, 4)
    } else {
        (vec![256, 1024, 4096], 256, 4, 4)
    };
    let (table, mut records) =
        table2::run_bench(&kls, tokens, reps, 2, batch, Some("results/table2.json"))
            .expect("table2");
    table.print();

    // Density sweep: CSR vs dense kernels at 1% and fully dense input.
    let (dtable, drecords) = table2::run_density(kls[0], tokens, reps, 2, batch, &[0.01, 1.0])
        .expect("table2 density sweep");
    dtable.print();
    records.extend(drecords);

    match bench::write_bench_json("table2_throughput", &records) {
        Ok(path) => eprintln!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write bench json: {e}"),
    }
}
