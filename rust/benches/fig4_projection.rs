//! Bench: Figure 4 — projection methods at p = 131072 across k and input
//! sparsity. Regenerates the figure's series (time per projection and
//! relative pairwise-distance error).
//!
//! Run: `cargo bench --bench fig4_projection`
//! Env: GRASS_BENCH_FAST=1 shrinks the sweep.

use grass::exp::fig4;

fn main() {
    let fast = std::env::var("GRASS_BENCH_FAST").is_ok();
    let ks: Vec<usize> = if fast {
        vec![512]
    } else {
        vec![512, 2048, 8192]
    };
    let budget = if fast { 30 } else { 300 };
    let table = fig4::run(&ks, budget, Some("results/fig4.json")).expect("fig4 run");
    table.print();
}
