//! Stub of the PJRT binding surface that `grass::runtime` consumes.
//!
//! The offline build environment has no PJRT plugin, so this crate mirrors
//! the types and signatures of the real bindings (`Literal`, `PjRtClient`,
//! `PjRtLoadedExecutable`, `HloModuleProto`, `XlaComputation`) but fails at
//! client-creation time with a clear message instead of executing HLO.
//! Everything above the runtime — compressors, attribution, the store, the
//! experiment harnesses that need no artifacts — runs unaffected, and the
//! runtime integration tests skip themselves unless `make artifacts` has
//! produced a manifest. Swapping this path dependency for real PJRT
//! bindings re-enables execution without touching `grass` code.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT backend unavailable: this build uses the vendored `xla` stub \
     (offline environment). Replace rust/vendor/xla with real PJRT bindings \
     to execute HLO artifacts";

/// Error type mirroring the real bindings' error enum (as a message).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Self {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor literal (carries no data in the stub).
#[derive(Debug, Clone, Default)]
pub struct Literal {}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal::default()
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal::default())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Device buffer handle returned by [`PjRtLoadedExecutable::execute`].
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// The PJRT client; creation fails in the stub with a clear message.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module text (content unused by the stub).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Reads the file so missing artifacts surface as an I/O error, but
    /// performs no HLO parsing.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path.as_ref()) {
            Ok(_) => Ok(HloModuleProto {}),
            Err(e) => Err(Error(format!("reading {}: {e}", path.as_ref().display()))),
        }
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn literal_construction_is_free() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
        let s = Literal::scalar(3i32);
        assert!(s.to_tuple().is_err());
    }
}
