//! Vendored, dependency-free drop-in for the subset of the `anyhow` API this
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait.
//!
//! The build environment is offline, so the real crate cannot be fetched
//! from a registry; this implementation keeps the exact call-site syntax so
//! the dependency can be swapped back for upstream `anyhow` without touching
//! application code. Errors are stored as a context chain of strings —
//! `Display` prints the outermost context (`{:#}` prints the full chain,
//! matching upstream), `Debug` prints a `Caused by:` trace.

use std::fmt;

/// A string-chained error value, API-compatible with `anyhow::Error` for
/// the operations this workspace performs.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything printable (what the [`anyhow!`] macro calls).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error {
            msg: msg.to_string(),
            cause: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, context: impl fmt::Display) -> Self {
        Error {
            msg: context.to_string(),
            cause: Some(Box::new(self)),
        }
    }

    /// The outermost message.
    pub fn message(&self) -> &str {
        &self.msg
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Self {
        Error {
            msg: e.to_string(),
            cause: e.source().map(|s| Box::new(Error::from_std(s))),
        }
    }
}

/// Iterator over an [`Error`]'s context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.cause.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.cause.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.cause.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`, which is
// what makes this blanket conversion coherent (same trick as upstream).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::from_std(&e)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Attach a fixed context message to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats() {
        let x = 7;
        let e = anyhow!("bad value {x} in {}", "spot");
        assert_eq!(e.to_string(), "bad value 7 in spot");
    }

    #[test]
    fn bail_returns_err() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged");
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged");
    }

    #[test]
    fn ensure_checks() {
        fn f(n: usize) -> Result<()> {
            ensure!(n > 2, "n = {n} too small");
            Ok(())
        }
        assert!(f(3).is_ok());
        assert!(f(1).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Result<(), _> = Err(io_err());
        let e = e
            .with_context(|| "loading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn context_on_anyhow_error_itself() {
        fn inner() -> Result<()> {
            bail!("inner failure");
        }
        let e = inner().context("outer step").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer step: inner failure");
    }
}
