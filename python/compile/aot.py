"""AOT compile path: lower every L2/L1 computation to HLO **text** and write
``artifacts/manifest.json`` describing shapes for the Rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; Python never runs again after this.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import models as M
from compile.kernels import factgrass as kfact
from compile.kernels import sjlt as ksjlt

# ---- batch-size contract with the Rust coordinator (runtime/registry.rs) ----
GRADS_BATCH = {"mlp": 16, "resnet_lite": 16, "gpt2_tiny": 4, "music": 8}
TRAIN_BATCH = {"mlp": 64, "resnet_lite": 32, "gpt2_tiny": 16, "music": 16}
LOSS_BATCH = {"mlp": 64, "resnet_lite": 32, "gpt2_tiny": 16, "music": 16}
HOOKS_BATCH = {"gpt2_tiny": 4, "music": 8}

# Demo kernel shapes (quickstart example + L1↔L3 cross-check).
SJLT_DEMO = {"b": 4, "p": 8192, "k": 256}
FACTGRASS_DEMO = {"t": 16, "ki": 32, "ko": 32, "k": 256}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _data_specs(model: M.Model, batch: int):
    """(x, y) input avals for a model; LMs take tokens only."""
    if model.name == "mlp":
        return [
            (jax.ShapeDtypeStruct((batch, 196), jnp.float32), _spec((batch, 196))),
            (jax.ShapeDtypeStruct((batch,), jnp.int32), _spec((batch,), "s32")),
        ]
    if model.name == "resnet_lite":
        return [
            (jax.ShapeDtypeStruct((batch, 3, 16, 16), jnp.float32), _spec((batch, 3, 16, 16))),
            (jax.ShapeDtypeStruct((batch,), jnp.int32), _spec((batch,), "s32")),
        ]
    # LMs: (tokens,)
    cfg = model.cfg
    return [
        (jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32), _spec((batch, cfg.seq), "s32")),
    ]


def lower_model_artifacts(model: M.Model, outdir: pathlib.Path, manifest: dict):
    p = model.p
    flat_aval = jax.ShapeDtypeStruct((p,), jnp.float32)
    lr_aval = jax.ShapeDtypeStruct((), jnp.float32)
    seed_aval = jax.ShapeDtypeStruct((), jnp.int32)
    is_lm = isinstance(model, M.TinyLM)

    def emit(name, fn, avals, in_specs, out_specs):
        path = outdir / f"{name}.hlo.txt"
        lowered = jax.jit(fn).lower(*avals)
        path.write_text(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "file": path.name,
            "inputs": in_specs,
            "outputs": out_specs,
        }
        print(f"  {name}: {path.stat().st_size/1e6:.2f} MB")

    # init(seed) -> flat params
    emit(
        f"{model.name}_init",
        lambda seed: (model.init(seed),),
        [seed_aval],
        [_spec((), "s32")],
        [_spec((p,))],
    )

    # train_step(flat, data..., lr) -> flat'
    tb = TRAIN_BATCH[model.name]
    data = _data_specs(model, tb)
    if is_lm:
        emit(
            f"{model.name}_train_step",
            lambda f, t, lr: (model.train_step(f, t, lr),),
            [flat_aval, data[0][0], lr_aval],
            [_spec((p,)), data[0][1], _spec((), "f32")],
            [_spec((p,))],
        )
    else:
        emit(
            f"{model.name}_train_step",
            lambda f, x, y, lr: (model.train_step(f, x, y, lr),),
            [flat_aval, data[0][0], data[1][0], lr_aval],
            [_spec((p,)), data[0][1], data[1][1], _spec((), "f32")],
            [_spec((p,))],
        )

    # loss_batch(flat, data...) -> (B,)
    lb = LOSS_BATCH[model.name]
    data = _data_specs(model, lb)
    if is_lm:
        emit(
            f"{model.name}_loss",
            lambda f, t: (model.loss_batch(f, t),),
            [flat_aval, data[0][0]],
            [_spec((p,)), data[0][1]],
            [_spec((lb,))],
        )
    else:
        emit(
            f"{model.name}_loss",
            lambda f, x, y: (model.loss_batch(f, x, y),),
            [flat_aval, data[0][0], data[1][0]],
            [_spec((p,)), data[0][1], data[1][1]],
            [_spec((lb,))],
        )

    # grads_batch(flat, data...) -> (B, P)
    gb = GRADS_BATCH[model.name]
    data = _data_specs(model, gb)
    if is_lm:
        emit(
            f"{model.name}_grads",
            lambda f, t: (model.grads_batch(f, t),),
            [flat_aval, data[0][0]],
            [_spec((p,)), data[0][1]],
            [_spec((gb, p))],
        )
    else:
        emit(
            f"{model.name}_grads",
            lambda f, x, y: (model.grads_batch(f, x, y),),
            [flat_aval, data[0][0], data[1][0]],
            [_spec((p,)), data[0][1], data[1][1]],
            [_spec((gb, p))],
        )

    model_meta = {"p": p, "params": [[s.name, list(s.shape)] for s in model.specs]}

    # hooks_batch (LoGra interface) for LMs
    if is_lm and model.name in HOOKS_BATCH:
        hb = HOOKS_BATCH[model.name]
        cfg = model.cfg
        layers = M.lm_linear_layers(cfg)
        tok_aval = jax.ShapeDtypeStruct((hb, cfg.seq), jnp.int32)
        out_specs = [_spec((hb, cfg.seq, d_in)) for (_, d_in, _) in layers] + [
            _spec((hb, cfg.seq, d_out)) for (_, _, d_out) in layers
        ]
        emit(
            f"{model.name}_hooks",
            lambda f, t: model.hooks_batch(f, t),
            [flat_aval, tok_aval],
            [_spec((p,)), _spec((hb, cfg.seq), "s32")],
            out_specs,
        )
        model_meta["layers"] = [[n, d_in, d_out] for (n, d_in, d_out) in layers]
        model_meta["seq"] = cfg.seq
        model_meta["vocab"] = cfg.vocab

    manifest["models"][model.name] = model_meta


def lower_kernel_artifacts(outdir: pathlib.Path, manifest: dict):
    """The L1 Pallas kernels as standalone executables (runtime tables are
    inputs, so the Rust side drives them with its own counter-based SJLT)."""
    b, p, k = SJLT_DEMO["b"], SJLT_DEMO["p"], SJLT_DEMO["k"]
    g = jax.ShapeDtypeStruct((b, p), jnp.float32)
    idx = jax.ShapeDtypeStruct((p,), jnp.int32)
    sgn = jax.ShapeDtypeStruct((p,), jnp.float32)
    lowered = jax.jit(lambda g_, i_, s_: (ksjlt.sjlt(g_, i_, s_, k),)).lower(g, idx, sgn)
    path = outdir / "kernel_sjlt.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    manifest["artifacts"]["kernel_sjlt"] = {
        "file": path.name,
        "inputs": [_spec((b, p)), _spec((p,), "s32"), _spec((p,))],
        "outputs": [_spec((b, k))],
        "meta": SJLT_DEMO,
    }
    print(f"  kernel_sjlt: {path.stat().st_size/1e6:.2f} MB")

    t, ki, ko, k2 = (
        FACTGRASS_DEMO["t"],
        FACTGRASS_DEMO["ki"],
        FACTGRASS_DEMO["ko"],
        FACTGRASS_DEMO["k"],
    )
    x = jax.ShapeDtypeStruct((t, ki), jnp.float32)
    dy = jax.ShapeDtypeStruct((t, ko), jnp.float32)
    idx2 = jax.ShapeDtypeStruct((ki * ko,), jnp.int32)
    sgn2 = jax.ShapeDtypeStruct((ki * ko,), jnp.float32)
    lowered = jax.jit(
        lambda x_, d_, i_, s_: (kfact.factgrass_compress(x_, d_, i_, s_, k2),)
    ).lower(x, dy, idx2, sgn2)
    path = outdir / "kernel_factgrass.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    manifest["artifacts"]["kernel_factgrass"] = {
        "file": path.name,
        "inputs": [_spec((t, ki)), _spec((t, ko)), _spec((ki * ko,), "s32"), _spec((ki * ko,))],
        "outputs": [_spec((k2,))],
        "meta": FACTGRASS_DEMO,
    }
    print(f"  kernel_factgrass: {path.stat().st_size/1e6:.2f} MB")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--models",
        default="mlp,resnet_lite,gpt2_tiny,music",
        help="comma-separated model subset",
    )
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    manifest = {
        "version": 1,
        "artifacts": {},
        "models": {},
        "batch_sizes": {
            "grads": GRADS_BATCH,
            "train": TRAIN_BATCH,
            "loss": LOSS_BATCH,
            "hooks": HOOKS_BATCH,
        },
    }
    for name in args.models.split(","):
        model = M.get_model(name.strip())
        print(f"[aot] lowering {model.name} (P = {model.p:,})")
        lower_model_artifacts(model, outdir, manifest)
    print("[aot] lowering L1 kernels")
    lower_kernel_artifacts(outdir, manifest)

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] wrote {outdir / 'manifest.json'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
