"""L2 JAX models — the compute graphs the Rust coordinator drives via PJRT.

Every model exposes the same flat-parameter interface so the Rust side only
ever handles a single ``f32[P]`` vector plus data tensors:

  * ``init(seed) -> params_flat``                      (f32[P])
  * ``train_step(params_flat, x, y, lr) -> params_flat'``  (SGD)
  * ``loss_batch(params_flat, x, y) -> f32[B]``        (per-sample losses)
  * ``grads_batch(params_flat, x, y) -> f32[B, P]``    (per-sample gradients,
    a single vmap∘grad — no recomputation, one backward per sample)

The transformer LM additionally exposes the LoGra interface needed by the
factorized compressors (paper §3.3.2):

  * ``hooks_batch(params_flat, tokens) ->``
    per-linear-layer ``(z_in (B,T,d_in), D z_out (B,T,d_out))`` pairs,
    captured with the zero-perturbation trick: ``y = W x + b + eps`` with
    ``eps ≡ 0``, so ``∂loss/∂eps`` *is* the pre-activation gradient.

Models (paper Table 3 analogues, scaled for the CPU testbed):
  * ``MLP``        — 3-layer MLP, 14×14 digit images (MNIST analogue).
  * ``ResNetLite`` — small residual convnet, 16×16×3 (CIFAR2 analogue).
  * ``TinyLM``     — decoder-only transformer; GPT2-small analogue and,
    with music hyper-parameters, the MusicTransformer/MAESTRO analogue.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Flat-parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Name and shape of one parameter tensor, in flat-vector order."""

    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        size = 1
        for d in self.shape:
            size *= d
        return size


def flatten_params(specs: list[ParamSpec], tree: dict) -> jnp.ndarray:
    return jnp.concatenate([tree[s.name].reshape(-1) for s in specs])


def unflatten_params(specs: list[ParamSpec], flat: jnp.ndarray) -> dict:
    out = {}
    off = 0
    for s in specs:
        out[s.name] = jax.lax.dynamic_slice_in_dim(flat, off, s.size).reshape(s.shape)
        off += s.size
    return out


def param_count(specs: list[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def _glorot(key, shape):
    fan_in = shape[-1] if len(shape) > 1 else shape[0]
    fan_out = shape[0] if len(shape) > 1 else shape[0]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


# --------------------------------------------------------------------------
# Model base: shared factory for the flat-parameter API
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    """A model with a flat-parameter functional API (see module docstring)."""

    name: str
    specs: list[ParamSpec]
    # loss_single(params_tree, x_single, y_single) -> scalar
    loss_single: Callable
    init_tree: Callable  # (key) -> params_tree

    @property
    def p(self) -> int:
        return param_count(self.specs)

    # ---- jax-level functions (lowered by aot.py) ----

    def init(self, seed: jnp.ndarray) -> jnp.ndarray:
        key = jax.random.PRNGKey(seed)
        return flatten_params(self.specs, self.init_tree(key))

    def loss_batch(self, flat, x, y):
        tree = unflatten_params(self.specs, flat)
        return jax.vmap(lambda xs, ys: self.loss_single(tree, xs, ys))(x, y)

    def mean_loss(self, flat, x, y):
        return jnp.mean(self.loss_batch(flat, x, y))

    def train_step(self, flat, x, y, lr):
        g = jax.grad(self.mean_loss)(flat, x, y)
        return flat - lr * g

    def grads_batch(self, flat, x, y):
        """Per-sample gradients as a (B, P) matrix — one vmap∘grad."""

        def grad_one(xs, ys):
            return jax.grad(lambda f: self.loss_single(unflatten_params(self.specs, f), xs, ys))(
                flat
            )

        return jax.vmap(grad_one)(x, y)


# --------------------------------------------------------------------------
# MLP (MNIST analogue)
# --------------------------------------------------------------------------


def make_mlp(d_in: int = 196, hidden: tuple[int, ...] = (256, 128), n_classes: int = 10) -> Model:
    """3-layer ReLU MLP on flattened digit images (paper Table 1a substrate).

    ReLU is deliberate: it induces the per-sample gradient sparsity the
    paper's §3.1 builds on (zero pre-activations kill whole gradient rows).
    """
    dims = (d_in,) + hidden + (n_classes,)
    specs = []
    for i in range(len(dims) - 1):
        specs.append(ParamSpec(f"w{i}", (dims[i + 1], dims[i])))
        specs.append(ParamSpec(f"b{i}", (dims[i + 1],)))

    def init_tree(key):
        tree = {}
        for i in range(len(dims) - 1):
            key, k1 = jax.random.split(key)
            tree[f"w{i}"] = _glorot(k1, (dims[i + 1], dims[i]))
            tree[f"b{i}"] = jnp.zeros((dims[i + 1],), dtype=jnp.float32)
        return tree

    n_layers = len(dims) - 1

    def loss_single(tree, x, y):
        h = x
        for i in range(n_layers):
            h = tree[f"w{i}"] @ h + tree[f"b{i}"]
            if i + 1 < n_layers:
                h = jax.nn.relu(h)
        logp = jax.nn.log_softmax(h)
        return -logp[y]

    return Model("mlp", specs, loss_single, init_tree)


# --------------------------------------------------------------------------
# ResNet-lite convnet (CIFAR2 analogue)
# --------------------------------------------------------------------------


def make_resnet_lite(
    image: int = 16, channels: int = 3, width: int = 16, n_classes: int = 2
) -> Model:
    """A small residual convnet: conv → 2 residual blocks (stride-2 between)
    → global-avg-pool → linear. ResNet9-in-miniature for Table 1b."""
    c1, c2 = width, width * 2
    specs = [
        ParamSpec("conv0", (c1, channels, 3, 3)),
        ParamSpec("b0", (c1,)),
        ParamSpec("conv1a", (c1, c1, 3, 3)),
        ParamSpec("b1a", (c1,)),
        ParamSpec("conv1b", (c1, c1, 3, 3)),
        ParamSpec("b1b", (c1,)),
        ParamSpec("conv2", (c2, c1, 3, 3)),  # stride 2
        ParamSpec("b2", (c2,)),
        ParamSpec("conv3a", (c2, c2, 3, 3)),
        ParamSpec("b3a", (c2,)),
        ParamSpec("conv3b", (c2, c2, 3, 3)),
        ParamSpec("b3b", (c2,)),
        ParamSpec("wout", (n_classes, c2)),
        ParamSpec("bout", (n_classes,)),
    ]

    def init_tree(key):
        tree = {}
        for s in specs:
            key, k1 = jax.random.split(key)
            if len(s.shape) == 4:
                fan_in = s.shape[1] * s.shape[2] * s.shape[3]
                tree[s.name] = jnp.sqrt(2.0 / fan_in) * jax.random.normal(
                    k1, s.shape, dtype=jnp.float32
                )
            elif len(s.shape) == 2:
                tree[s.name] = _glorot(k1, s.shape)
            else:
                tree[s.name] = jnp.zeros(s.shape, dtype=jnp.float32)
        return tree

    def conv(x, w, b, stride=1):
        # x: (C, H, W) single sample -> NCHW with N=1
        y = jax.lax.conv_general_dilated(
            x[None],
            w,
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )[0]
        return y + b[:, None, None]

    def loss_single(tree, x, y):
        h = jax.nn.relu(conv(x, tree["conv0"], tree["b0"]))
        # residual block 1
        r = jax.nn.relu(conv(h, tree["conv1a"], tree["b1a"]))
        r = conv(r, tree["conv1b"], tree["b1b"])
        h = jax.nn.relu(h + r)
        # downsample
        h = jax.nn.relu(conv(h, tree["conv2"], tree["b2"], stride=2))
        # residual block 2
        r = jax.nn.relu(conv(h, tree["conv3a"], tree["b3a"]))
        r = conv(r, tree["conv3b"], tree["b3b"])
        h = jax.nn.relu(h + r)
        # global average pool + linear
        feat = h.mean(axis=(1, 2))
        logits = tree["wout"] @ feat + tree["bout"]
        logp = jax.nn.log_softmax(logits)
        return -logp[y]

    return Model("resnet_lite", specs, loss_single, init_tree)


# --------------------------------------------------------------------------
# Tiny decoder-only transformer LM (GPT2-small / MusicTransformer analogue)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LmConfig:
    vocab: int = 256
    seq: int = 64
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


#: The linear layers hooked for LoGra/FactGraSS, per block:
#: (name, d_in, d_out) — qkv fused, attention output, and the two MLP mats.
def lm_linear_layers(cfg: LmConfig) -> list[tuple[str, int, int]]:
    layers = []
    for b in range(cfg.n_layers):
        layers.append((f"blk{b}.qkv", cfg.d_model, 3 * cfg.d_model))
        layers.append((f"blk{b}.proj", cfg.d_model, cfg.d_model))
        layers.append((f"blk{b}.fc1", cfg.d_model, cfg.d_ff))
        layers.append((f"blk{b}.fc2", cfg.d_ff, cfg.d_model))
    return layers


class TinyLM(Model):
    """Decoder-only transformer with pre-LN blocks and a tied LM head."""

    def __init__(self, cfg: LmConfig, name: str = "lm"):
        self.cfg = cfg
        specs = [
            ParamSpec("embed", (cfg.vocab, cfg.d_model)),
            ParamSpec("pos", (cfg.seq, cfg.d_model)),
        ]
        for b in range(cfg.n_layers):
            specs += [
                ParamSpec(f"blk{b}.ln1_g", (cfg.d_model,)),
                ParamSpec(f"blk{b}.ln1_b", (cfg.d_model,)),
                ParamSpec(f"blk{b}.qkv_w", (3 * cfg.d_model, cfg.d_model)),
                ParamSpec(f"blk{b}.qkv_b", (3 * cfg.d_model,)),
                ParamSpec(f"blk{b}.proj_w", (cfg.d_model, cfg.d_model)),
                ParamSpec(f"blk{b}.proj_b", (cfg.d_model,)),
                ParamSpec(f"blk{b}.ln2_g", (cfg.d_model,)),
                ParamSpec(f"blk{b}.ln2_b", (cfg.d_model,)),
                ParamSpec(f"blk{b}.fc1_w", (cfg.d_ff, cfg.d_model)),
                ParamSpec(f"blk{b}.fc1_b", (cfg.d_ff,)),
                ParamSpec(f"blk{b}.fc2_w", (cfg.d_model, cfg.d_ff)),
                ParamSpec(f"blk{b}.fc2_b", (cfg.d_model,)),
            ]
        specs += [ParamSpec("lnf_g", (cfg.d_model,)), ParamSpec("lnf_b", (cfg.d_model,))]

        def init_tree(key):
            tree = {}
            for s in specs:
                key, k1 = jax.random.split(key)
                if s.name.endswith("_g"):
                    tree[s.name] = jnp.ones(s.shape, dtype=jnp.float32)
                elif len(s.shape) == 1:
                    tree[s.name] = jnp.zeros(s.shape, dtype=jnp.float32)
                elif s.name in ("embed", "pos"):
                    tree[s.name] = 0.02 * jax.random.normal(k1, s.shape, dtype=jnp.float32)
                else:
                    tree[s.name] = _glorot(k1, s.shape)
            return tree

        super().__init__(
            name=name,
            specs=specs,
            loss_single=self._loss_single,
            init_tree=init_tree,
        )

    # ---- forward ----

    @staticmethod
    def _ln(x, g, b):
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        return g * (x - mu) * jax.lax.rsqrt(var + 1e-5) + b

    def _block(self, tree, b, h, eps=None):
        """One transformer block; ``eps`` optionally carries the
        zero-perturbations for pre-activation gradient capture, alongside a
        list collecting layer inputs."""
        cfg = self.cfg
        T = h.shape[0]

        def lin(x, w, bb, tag):
            y = x @ w.T + bb
            if eps is not None:
                eps["x"].append((tag, x))
                y = y + eps["eps"][tag]
            return y

        x1 = self._ln(h, tree[f"blk{b}.ln1_g"], tree[f"blk{b}.ln1_b"])
        qkv = lin(x1, tree[f"blk{b}.qkv_w"], tree[f"blk{b}.qkv_b"], f"blk{b}.qkv")
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = cfg.head_dim
        q = q.reshape(T, cfg.n_heads, hd).transpose(1, 0, 2)
        k = k.reshape(T, cfg.n_heads, hd).transpose(1, 0, 2)
        v = v.reshape(T, cfg.n_heads, hd).transpose(1, 0, 2)
        att = (q @ k.transpose(0, 2, 1)) / jnp.sqrt(hd).astype(jnp.float32)
        mask = jnp.tril(jnp.ones((T, T), dtype=bool))
        att = jnp.where(mask[None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(1, 0, 2).reshape(T, cfg.d_model)
        h = h + lin(o, tree[f"blk{b}.proj_w"], tree[f"blk{b}.proj_b"], f"blk{b}.proj")
        x2 = self._ln(h, tree[f"blk{b}.ln2_g"], tree[f"blk{b}.ln2_b"])
        f = jax.nn.gelu(lin(x2, tree[f"blk{b}.fc1_w"], tree[f"blk{b}.fc1_b"], f"blk{b}.fc1"))
        h = h + lin(f, tree[f"blk{b}.fc2_w"], tree[f"blk{b}.fc2_b"], f"blk{b}.fc2")
        return h

    def _logits(self, tree, tokens, eps=None):
        cfg = self.cfg
        h = tree["embed"][tokens] + tree["pos"]
        for b in range(cfg.n_layers):
            h = self._block(tree, b, h, eps)
        h = self._ln(h, tree["lnf_g"], tree["lnf_b"])
        return h @ tree["embed"].T  # tied head

    def _loss_single(self, tree, tokens, _y_unused=None):
        """Next-token cross-entropy over one (T,) token sequence."""
        logits = self._logits(tree, tokens)  # (T, V)
        logp = jax.nn.log_softmax(logits[:-1])
        tgt = tokens[1:]
        return -jnp.take_along_axis(logp, tgt[:, None], axis=1).mean()

    # LM data is (tokens,) only — adapt the generic API.
    def loss_batch(self, flat, tokens, y=None):
        tree = unflatten_params(self.specs, flat)
        return jax.vmap(lambda t: self._loss_single(tree, t))(tokens)

    def mean_loss(self, flat, tokens, y=None):
        return jnp.mean(self.loss_batch(flat, tokens))

    def train_step(self, flat, tokens, lr, y=None):
        g = jax.grad(lambda f: jnp.mean(self.loss_batch(f, tokens)))(flat)
        return flat - lr * g

    def grads_batch(self, flat, tokens, y=None):
        def grad_one(t):
            return jax.grad(
                lambda f: self._loss_single(unflatten_params(self.specs, f), t)
            )(flat)

        return jax.vmap(grad_one)(tokens)

    # ---- LoGra hook capture ----

    def hooks_single(self, flat, tokens):
        """Per-linear-layer (z_in, D z_out) for one sequence.

        Returns two tuples ordered as ``lm_linear_layers(cfg)``:
        xs[i] is (T, d_in_i), dys[i] is (T, d_out_i).
        """
        tree = unflatten_params(self.specs, flat)
        layers = lm_linear_layers(self.cfg)
        T = self.cfg.seq

        def loss_wrt_eps(eps_list):
            eps = {
                "eps": {name: e for (name, _, _), e in zip(layers, eps_list)},
                "x": [],
            }
            logits = self._logits(tree, tokens, eps)
            logp = jax.nn.log_softmax(logits[:-1])
            tgt = tokens[1:]
            loss = -jnp.take_along_axis(logp, tgt[:, None], axis=1).mean()
            xs = {tag: x for tag, x in eps["x"]}
            return loss, tuple(xs[name] for (name, _, _) in layers)

        zeros = tuple(jnp.zeros((T, d_out), dtype=jnp.float32) for (_, _, d_out) in layers)
        dys, xs = jax.grad(loss_wrt_eps, has_aux=True)(zeros)
        return xs, dys

    def hooks_batch(self, flat, tokens):
        """Batched hook capture: returns (xs..., dys...) flattened for AOT —
        2·L arrays, first all xs (B,T,d_in_l), then all dys (B,T,d_out_l)."""
        xs, dys = jax.vmap(lambda t: self.hooks_single(flat, t))(tokens)
        return tuple(xs) + tuple(dys)


def make_gpt2_tiny() -> TinyLM:
    """GPT2-small analogue for Table 1d (scaled; see DESIGN.md §5)."""
    return TinyLM(LmConfig(vocab=256, seq=64, d_model=128, n_heads=4, n_layers=2, d_ff=256),
                  name="gpt2_tiny")


def make_music_transformer() -> TinyLM:
    """MusicTransformer/MAESTRO analogue for Table 1c: event-vocabulary LM."""
    return TinyLM(LmConfig(vocab=128, seq=32, d_model=64, n_heads=4, n_layers=2, d_ff=128),
                  name="music")


# Registry used by aot.py and tests.
MODELS: dict[str, Callable[[], Model]] = {
    "mlp": make_mlp,
    "resnet_lite": make_resnet_lite,
    "gpt2_tiny": make_gpt2_tiny,
    "music": make_music_transformer,
}


@functools.cache
def get_model(name: str) -> Model:
    return MODELS[name]()
