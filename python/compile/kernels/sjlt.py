"""L1 Pallas SJLT kernel — the paper's CUDA scatter kernel, rethought for TPU.

The CUDA kernel (paper §3.1, App B.4.1) partitions *input* dimensions across
threads to tame atomic scatter-add contention on the small output vector.
TPUs have no atomic VMEM scatter and irregular writes stall the VPU, so a
mechanical port would be slow. Instead we express each input tile's
contribution as a **one-hot matmul** on the MXU:

    out += onehot(idx_tile, k)^T-free form:  (g_tile * sgn_tile) @ onehot

where ``onehot`` is generated on the fly in VMEM from the streamed ``idx``
tile (never stored in HBM). The grid reduces over input tiles into a VMEM
accumulator of shape ``(B, k)`` — contention-free by construction, exactly
the property the CUDA kernel buys with its thread layout.

VMEM budget (the BlockSpec contract): per grid step we hold
``B·TB + 2·TB + B·k + TB·k`` f32. At the defaults (B=8, TB=512, k=4096)
that is ~10.5 MB — under the ~16 MB/core budget, with the ``TB×k`` one-hot
as the dominant term; shrink TB to trade MXU efficiency for headroom.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so lowering stays in plain HLO (see DESIGN.md
§Hardware-Adaptation for the real-TPU performance estimate).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default input-tile length. Must divide the (padded) input dimension.
DEFAULT_TB = 512


def _sjlt_kernel(g_ref, idx_ref, sgn_ref, o_ref, *, k: int, tb: int):
    """One grid step: accumulate one input tile's contribution into o_ref.

    g_ref:   (B, TB) input tile
    idx_ref: (TB,)   bucket ids for this tile
    sgn_ref: (TB,)   ±1 signs for this tile
    o_ref:   (B, k)  VMEM accumulator (same block for every step)
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    idx = idx_ref[...]
    sgn = sgn_ref[...].astype(g.dtype)
    # On-the-fly one-hot: (TB, k). iota along k compares against idx.
    cols = jax.lax.broadcasted_iota(jnp.int32, (tb, k), 1)
    onehot = (idx[:, None] == cols).astype(g.dtype)
    # (B, TB) @ (TB, k) -> (B, k): the MXU-shaped segment-sum.
    o_ref[...] += (g * sgn[None, :]) @ onehot


def sjlt(
    g: jnp.ndarray,
    idx: jnp.ndarray,
    sgn: jnp.ndarray,
    k: int,
    *,
    tb: int = DEFAULT_TB,
    interpret: bool = True,
) -> jnp.ndarray:
    """SJLT (s=1) of a batch of vectors via the Pallas one-hot-matmul kernel.

    Args:
      g: ``(B, p)`` float32 inputs.
      idx: ``(p,)`` int32 buckets in ``[0, k)``.
      sgn: ``(p,)`` float32 ±1 signs.
      k: output dimension.
      tb: input-tile length (VMEM knob).
      interpret: keep True on CPU (see module docstring).

    Returns:
      ``(B, k)`` float32 compressed batch.
    """
    b, p = g.shape
    assert idx.shape == (p,) and sgn.shape == (p,), "idx/sgn must be (p,)"
    tile = min(tb, p)
    # Pad p up to a multiple of the tile; padded lanes get bucket 0 with
    # sign 0 so they contribute nothing.
    pad = (-p) % tile
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)))
        idx = jnp.pad(idx, (0, pad))
        sgn = jnp.pad(sgn, (0, pad))
    p2 = p + pad
    grid = (p2 // tile,)
    kernel = functools.partial(_sjlt_kernel, k=k, tb=tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, tile), lambda i: (0, i)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), g.dtype),
        interpret=interpret,
    )(g, idx, sgn)


def sjlt_tables(p: int, k: int, seed: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Generate (idx, sgn) tables compatible in distribution with the Rust
    counter-based SJLT (uniform buckets, Rademacher signs). Used by tests
    and the AOT demo artifacts; the Rust coordinator passes its own tables
    at runtime so both layers agree on the projection.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    idx = jax.random.randint(k1, (p,), 0, k, dtype=jnp.int32)
    sgn = jax.random.rademacher(k2, (p,), dtype=jnp.float32)
    return idx, sgn
