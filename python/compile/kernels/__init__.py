"""L1 Pallas kernels for GraSS: SJLT sparse projection and the FactGraSS
factorized compress step, plus pure-jnp oracles (ref.py)."""
