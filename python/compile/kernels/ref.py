"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (including the
hypothesis shape/dtype sweeps in ``python/tests``).
"""

from __future__ import annotations

import jax.numpy as jnp


def sjlt_ref(g: jnp.ndarray, idx: jnp.ndarray, sgn: jnp.ndarray, k: int) -> jnp.ndarray:
    """Reference SJLT with s=1: scatter-add ``sgn[j] * g[..., j]`` into bucket
    ``idx[j]``.

    Args:
      g: ``(..., p)`` input vectors.
      idx: ``(p,)`` int32 bucket per input coordinate, values in ``[0, k)``.
      sgn: ``(p,)`` float ±1 signs.
      k: output dimension.

    Returns:
      ``(..., k)`` compressed vectors.
    """
    signed = g * sgn  # broadcast over leading dims
    out_shape = g.shape[:-1] + (k,)
    flat = signed.reshape(-1, g.shape[-1])
    out = jnp.zeros((flat.shape[0], k), dtype=g.dtype)
    out = out.at[:, idx].add(flat)
    return out.reshape(out_shape)


def kron_reconstruct_ref(x: jnp.ndarray, dy: jnp.ndarray) -> jnp.ndarray:
    """Reference sparsified-gradient reconstruction (paper Eq. 2/3):

    ``g'[a*ko + b] = sum_t x[t, a] * dy[t, b]``  ==  ``vec(x^T dy)``.

    Args:
      x: ``(T, ki)`` masked layer inputs.
      dy: ``(T, ko)`` masked pre-activation gradients.

    Returns:
      ``(ki * ko,)`` reconstructed sparsified gradient.
    """
    return (x.T @ dy).reshape(-1)


def factgrass_ref(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    idx: jnp.ndarray,
    sgn: jnp.ndarray,
    k: int,
) -> jnp.ndarray:
    """Reference FactGraSS stage 2+3: Kronecker reconstruction then SJLT.

    Args:
      x: ``(T, ki)`` masked inputs; dy: ``(T, ko)`` masked output grads.
      idx/sgn: SJLT tables over ``p' = ki * ko``.
      k: target compressed dimension.
    """
    g = kron_reconstruct_ref(x, dy)
    return sjlt_ref(g, idx, sgn, k)
