"""L1 Pallas FactGraSS kernel — stages 2+3 of the factorized compress step.

Given the *already-masked* factors of one linear layer,

    x'  : (T, ki)  masked inputs,
    dy' : (T, ko)  masked pre-activation gradients,

the paper's FactGraSS (§3.3.2) computes the sparsified gradient
``g' = vec(x'^T dy')`` (Kronecker reconstruction, Eq. 3) and then SJLTs it
down to ``k``. On TPU both stages are MXU matmuls:

  * reconstruction is a ``(ki, T) @ (T, ko)`` contraction — systolic-array
    native, never touching the full ``d_in·d_out`` gradient;
  * the SJLT is the one-hot matmul from ``kernels.sjlt`` over the flattened
    ``ki·ko`` vector.

Fusing them in one kernel keeps ``g'`` in VMEM: ``ki·ko`` f32 (e.g. 64·64 =
16 KB) plus the one-hot tile, comfortably inside the VMEM budget, so HBM
traffic is just ``T(ki+ko) + k`` — the paper's O(k') space claim, literally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _factgrass_kernel(x_ref, dy_ref, idx_ref, sgn_ref, o_ref, *, k: int, ki: int, ko: int):
    """Single-block kernel: reconstruction + SJLT for one sample.

    x_ref:   (T, ki); dy_ref: (T, ko); idx_ref/sgn_ref: (ki*ko,)
    o_ref:   (k,)
    """
    x = x_ref[...]
    dy = dy_ref[...]
    # Stage 2: Kronecker reconstruction g'[a, b] = sum_t x[t, a] dy[t, b].
    g = jax.lax.dot_general(
        x, dy, dimension_numbers=(((0,), (0,)), ((), ()))
    )  # (ki, ko)
    gflat = g.reshape(1, ki * ko)
    # Stage 3: SJLT via on-the-fly one-hot matmul (see kernels.sjlt).
    idx = idx_ref[...]
    sgn = sgn_ref[...].astype(x.dtype)
    cols = jax.lax.broadcasted_iota(jnp.int32, (ki * ko, k), 1)
    onehot = (idx[:, None] == cols).astype(x.dtype)
    o_ref[...] = ((gflat * sgn[None, :]) @ onehot)[0]


def factgrass_compress(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    idx: jnp.ndarray,
    sgn: jnp.ndarray,
    k: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """FactGraSS stages 2+3 for one sample.

    Args:
      x: ``(T, ki)`` masked inputs; dy: ``(T, ko)`` masked output grads.
      idx: ``(ki*ko,)`` int32 SJLT buckets; sgn: ``(ki*ko,)`` ±1 signs.
      k: target compressed dimension.

    Returns:
      ``(k,)`` compressed layer gradient.
    """
    t, ki = x.shape
    t2, ko = dy.shape
    assert t == t2, f"sequence mismatch: {t} vs {t2}"
    assert idx.shape == (ki * ko,) and sgn.shape == (ki * ko,)
    kernel = functools.partial(_factgrass_kernel, k=k, ki=ki, ko=ko)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((k,), x.dtype),
        interpret=interpret,
    )(x, dy, idx, sgn)


def factgrass_compress_batch(
    x: jnp.ndarray,
    dy: jnp.ndarray,
    idx: jnp.ndarray,
    sgn: jnp.ndarray,
    k: int,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched FactGraSS: ``x (B,T,ki)``, ``dy (B,T,ko)`` → ``(B, k)``."""
    fn = functools.partial(factgrass_compress, k=k, interpret=interpret)
    return jax.vmap(lambda xb, db: fn(xb, db, idx, sgn))(x, dy)
