"""Thin re-export kept for the canonical repo layout; see ``models.py``."""

from compile.models import (  # noqa: F401
    MODELS,
    LmConfig,
    Model,
    ParamSpec,
    TinyLM,
    get_model,
    lm_linear_layers,
    make_gpt2_tiny,
    make_mlp,
    make_music_transformer,
    make_resnet_lite,
)
