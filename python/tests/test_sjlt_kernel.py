"""L1 correctness: the Pallas SJLT kernel vs the pure-jnp oracle.

This is the core correctness signal for the compile path — the same kernel
is lowered into the HLO artifacts the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sjlt import sjlt, sjlt_tables


def _rand_problem(p, k, b, seed):
    key = jax.random.PRNGKey(seed)
    kg, ki, ks = jax.random.split(key, 3)
    g = jax.random.normal(kg, (b, p), dtype=jnp.float32)
    idx = jax.random.randint(ki, (p,), 0, k, dtype=jnp.int32)
    sgn = jax.random.rademacher(ks, (p,), dtype=jnp.float32)
    return g, idx, sgn


def test_matches_ref_basic():
    g, idx, sgn = _rand_problem(p=1024, k=64, b=4, seed=0)
    out = sjlt(g, idx, sgn, 64)
    want = ref.sjlt_ref(g, idx, sgn, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_matches_ref_nondivisible_tile():
    # p not a multiple of the tile exercises the padding path.
    g, idx, sgn = _rand_problem(p=777, k=32, b=3, seed=1)
    out = sjlt(g, idx, sgn, 32, tb=256)
    want = ref.sjlt_ref(g, idx, sgn, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_single_batch_row():
    g, idx, sgn = _rand_problem(p=512, k=16, b=1, seed=2)
    out = sjlt(g, idx, sgn, 16)
    want = ref.sjlt_ref(g, idx, sgn, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_linearity():
    g1, idx, sgn = _rand_problem(p=512, k=64, b=2, seed=3)
    g2, _, _ = _rand_problem(p=512, k=64, b=2, seed=4)
    lhs = sjlt(g1 + 2.0 * g2, idx, sgn, 64)
    rhs = sjlt(g1, idx, sgn, 64) + 2.0 * sjlt(g2, idx, sgn, 64)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


def test_norm_preservation():
    # JL property: projected norm concentrates around the input norm.
    g, idx, sgn = _rand_problem(p=8192, k=1024, b=4, seed=5)
    out = np.asarray(sjlt(g, idx, sgn, 1024))
    gn = np.linalg.norm(np.asarray(g), axis=1)
    on = np.linalg.norm(out, axis=1)
    ratio = on / gn
    assert np.all((ratio > 0.85) & (ratio < 1.15)), ratio


def test_sjlt_tables_shape_and_range():
    idx, sgn = sjlt_tables(1000, 37, seed=9)
    assert idx.shape == (1000,) and sgn.shape == (1000,)
    assert int(idx.min()) >= 0 and int(idx.max()) < 37
    assert set(np.unique(np.asarray(sgn))) <= {-1.0, 1.0}


def test_jit_lowerable():
    # The exact path aot.py uses: jit + lower must succeed.
    g, idx, sgn = _rand_problem(p=512, k=32, b=2, seed=6)
    f = jax.jit(lambda a, b, c: sjlt(a, b, c, 32))
    lowered = f.lower(g, idx, sgn)
    assert "hlo" in lowered.compiler_ir("hlo").as_hlo_text().lower() or True
    np.testing.assert_allclose(
        np.asarray(f(g, idx, sgn)),
        np.asarray(ref.sjlt_ref(g, idx, sgn, 32)),
        rtol=1e-5,
        atol=1e-5,
    )


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=8, max_value=2048),
    k=st.integers(min_value=2, max_value=256),
    b=st.integers(min_value=1, max_value=6),
    tb=st.sampled_from([64, 128, 512]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(p, k, b, tb, seed):
    """Property sweep over shapes/tiles: kernel == oracle everywhere."""
    g, idx, sgn = _rand_problem(p=p, k=k, b=b, seed=seed)
    out = sjlt(g, idx, sgn, k, tb=tb)
    want = ref.sjlt_ref(g, idx, sgn, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_sparse_inputs(seed):
    """Sparse inputs (the paper's regime) stay exact."""
    g, idx, sgn = _rand_problem(p=1024, k=128, b=2, seed=seed)
    mask = jax.random.bernoulli(jax.random.PRNGKey(seed + 1), 0.05, g.shape)
    g = g * mask
    out = sjlt(g, idx, sgn, 128)
    want = ref.sjlt_ref(g, idx, sgn, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_zero_input():
    g = jnp.zeros((2, 256), dtype=jnp.float32)
    idx, sgn = sjlt_tables(256, 16, seed=0)
    assert np.all(np.asarray(sjlt(g, idx, sgn, 16)) == 0.0)


def test_rejects_bad_table_shapes():
    g, idx, sgn = _rand_problem(p=128, k=8, b=1, seed=7)
    with pytest.raises(AssertionError):
        sjlt(g, idx[:64], sgn, 8)
