"""L2 correctness: model shapes, gradient consistency, hook capture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as M


@pytest.fixture(scope="module", params=["mlp", "resnet_lite", "music"])
def model(request):
    return M.get_model(request.param)


def _data_for(model, b, seed=0):
    key = jax.random.PRNGKey(seed)
    if model.name == "mlp":
        x = jax.random.normal(key, (b, 196), dtype=jnp.float32)
        y = jax.random.randint(key, (b,), 0, 10, dtype=jnp.int32)
        return (x, y)
    if model.name == "resnet_lite":
        x = jax.random.normal(key, (b, 3, 16, 16), dtype=jnp.float32)
        y = jax.random.randint(key, (b,), 0, 2, dtype=jnp.int32)
        return (x, y)
    tokens = jax.random.randint(key, (b, model.cfg.seq), 0, model.cfg.vocab, dtype=jnp.int32)
    return (tokens,)


def test_init_is_deterministic_and_sized(model):
    f1 = model.init(jnp.int32(7))
    f2 = model.init(jnp.int32(7))
    f3 = model.init(jnp.int32(8))
    assert f1.shape == (model.p,)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert not np.array_equal(np.asarray(f1), np.asarray(f3))


def test_loss_batch_shape_and_finite(model):
    flat = model.init(jnp.int32(0))
    data = _data_for(model, 4)
    losses = model.loss_batch(flat, *data)
    assert losses.shape == (4,)
    assert np.all(np.isfinite(np.asarray(losses)))
    assert np.all(np.asarray(losses) > 0)


def test_grads_batch_matches_individual_grad(model):
    flat = model.init(jnp.int32(1))
    data = _data_for(model, 3)
    grads = np.asarray(model.grads_batch(flat, *data))
    assert grads.shape == (3, model.p)
    # mean of per-sample grads == batch grad of mean loss
    batch_grad = np.asarray(
        jax.grad(lambda f: jnp.mean(model.loss_batch(f, *data)))(flat)
    )
    np.testing.assert_allclose(grads.mean(axis=0), batch_grad, rtol=2e-3, atol=2e-4)


def test_train_step_reduces_loss(model):
    flat = model.init(jnp.int32(2))
    data = _data_for(model, 8)
    l0 = float(jnp.mean(model.loss_batch(flat, *data)))
    f = flat
    for _ in range(10):
        if isinstance(model, M.TinyLM):
            f = model.train_step(f, data[0], jnp.float32(0.5))
        else:
            f = model.train_step(f, *data, jnp.float32(0.5))
    l1 = float(jnp.mean(model.loss_batch(f, *data)))
    assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"


def test_mlp_per_sample_gradients_are_sparse():
    """Paper §3.1: ReLU nets induce sparse per-sample gradients."""
    model = M.get_model("mlp")
    flat = model.init(jnp.int32(3))
    data = _data_for(model, 8)
    grads = np.asarray(model.grads_batch(flat, *data))
    frac_zero = float((grads == 0.0).mean())
    assert frac_zero > 0.2, f"expected ReLU-induced sparsity, got {frac_zero:.3f}"


def test_lm_hooks_reconstruct_weight_gradient():
    """The LoGra identity (Eq. 2): sum_t x_t ⊗ dy_t == dL/dW for every
    hooked linear layer — validates the zero-perturbation capture."""
    model = M.get_model("music")
    flat = model.init(jnp.int32(4))
    tokens = _data_for(model, 1)[0][0]
    xs, dys = model.hooks_single(flat, tokens)
    layers = M.lm_linear_layers(model.cfg)

    grads = jax.grad(
        lambda f: model._loss_single(M.unflatten_params(model.specs, f), tokens)
    )(flat)
    tree = M.unflatten_params(model.specs, grads)

    for (name, d_in, d_out), x, dy in zip(layers, xs, dys):
        # W is stored (d_out, d_in); dL/dW = dy^T x
        want = np.asarray(tree[f"{name}_w"])
        got = np.asarray(dy.T @ x)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-4, err_msg=name)
        assert x.shape == (model.cfg.seq, d_in)
        assert dy.shape == (model.cfg.seq, d_out)


def test_lm_hooks_batch_layout():
    model = M.get_model("music")
    flat = model.init(jnp.int32(5))
    key = jax.random.PRNGKey(9)
    tokens = jax.random.randint(key, (2, model.cfg.seq), 0, model.cfg.vocab, dtype=jnp.int32)
    outs = model.hooks_batch(flat, tokens)
    layers = M.lm_linear_layers(model.cfg)
    assert len(outs) == 2 * len(layers)
    for i, (name, d_in, d_out) in enumerate(layers):
        assert outs[i].shape == (2, model.cfg.seq, d_in), name
        assert outs[len(layers) + i].shape == (2, model.cfg.seq, d_out), name


def test_param_specs_cover_flat_vector(model):
    total = sum(s.size for s in model.specs)
    assert total == model.p
    # round-trip
    flat = model.init(jnp.int32(6))
    tree = M.unflatten_params(model.specs, flat)
    back = M.flatten_params(model.specs, tree)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_model_registry():
    assert set(M.MODELS) == {"mlp", "resnet_lite", "gpt2_tiny", "music"}
    assert M.get_model("mlp") is M.get_model("mlp")  # cached
