"""L1 correctness: the Pallas FactGraSS kernel (Kron-reconstruct + SJLT)
vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.factgrass import factgrass_compress, factgrass_compress_batch


def _problem(t, ki, ko, k, seed):
    key = jax.random.PRNGKey(seed)
    kx, kd, kidx, ksgn = jax.random.split(key, 4)
    x = jax.random.normal(kx, (t, ki), dtype=jnp.float32)
    dy = jax.random.normal(kd, (t, ko), dtype=jnp.float32)
    idx = jax.random.randint(kidx, (ki * ko,), 0, k, dtype=jnp.int32)
    sgn = jax.random.rademacher(ksgn, (ki * ko,), dtype=jnp.float32)
    return x, dy, idx, sgn


def test_matches_ref():
    x, dy, idx, sgn = _problem(t=8, ki=16, ko=12, k=32, seed=0)
    out = factgrass_compress(x, dy, idx, sgn, 32)
    want = ref.factgrass_ref(x, dy, idx, sgn, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_reconstruction_is_sum_of_kroneckers():
    # The kernel's stage-2 must equal sum_t x_t ⊗ dy_t (paper Eq. 3).
    x, dy, idx, sgn = _problem(t=5, ki=4, ko=3, k=12, seed=1)
    explicit = jnp.zeros((4 * 3,), dtype=jnp.float32)
    for ti in range(5):
        explicit = explicit + jnp.kron(x[ti], dy[ti])
    want = ref.sjlt_ref(explicit, idx, sgn, 12)
    out = factgrass_compress(x, dy, idx, sgn, 12)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_batch_matches_loop():
    b = 3
    key = jax.random.PRNGKey(2)
    kx, kd = jax.random.split(key)
    x = jax.random.normal(kx, (b, 8, 16), dtype=jnp.float32)
    dy = jax.random.normal(kd, (b, 8, 12), dtype=jnp.float32)
    _, _, idx, sgn = _problem(t=8, ki=16, ko=12, k=24, seed=3)
    batched = factgrass_compress_batch(x, dy, idx, sgn, 24)
    for i in range(b):
        one = factgrass_compress(x[i], dy[i], idx, sgn, 24)
        np.testing.assert_allclose(np.asarray(batched[i]), np.asarray(one), rtol=1e-5, atol=1e-5)


def test_never_materializes_full_gradient():
    # Structural check: for d_in = d_out = 256 with ki = ko = 8, the lowered
    # HLO must not contain a 256·256 = 65536-element intermediate.
    x, dy, idx, sgn = _problem(t=4, ki=8, ko=8, k=16, seed=4)
    lowered = jax.jit(lambda a, b, c, d: factgrass_compress(a, b, c, d, 16)).lower(
        x, dy, idx, sgn
    )
    text = lowered.compiler_ir("hlo").as_hlo_text()
    assert "65536" not in text


def test_linearity_in_dy():
    x, dy, idx, sgn = _problem(t=8, ki=16, ko=12, k=32, seed=5)
    out1 = factgrass_compress(x, dy, idx, sgn, 32)
    out2 = factgrass_compress(x, 3.0 * dy, idx, sgn, 32)
    np.testing.assert_allclose(np.asarray(out2), 3.0 * np.asarray(out1), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=32),
    ki=st.integers(min_value=2, max_value=32),
    ko=st.integers(min_value=2, max_value=32),
    k=st.integers(min_value=2, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(t, ki, ko, k, seed):
    x, dy, idx, sgn = _problem(t=t, ki=ki, ko=ko, k=k, seed=seed)
    out = factgrass_compress(x, dy, idx, sgn, k)
    want = ref.factgrass_ref(x, dy, idx, sgn, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3, atol=1e-3)
